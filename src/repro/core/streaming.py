"""Streaming coreset construction (paper Alg. 2 "StreamCoreset" + the
tau-controlled doubling variant of §5.2), as a single jit'd lax.scan.

The scan is exposed as a resumable *ingestion API* — the substrate of the
online serving layer (serve/diversity):

    st = init_stream_state(d, gamma, spec, k, tau)
    st = ingest_batch(st, batch, cats, valid, spec, caps, k, tau,
                      base_index=offset)     # any number of times
    coreset = snapshot_coreset(st)

``stream_coreset`` (the one-shot entry point) is now a thin wrapper over
these three; batched ingestion is bit-identical to a single pass because the
scan branches only on ``st.n_seen``.

The scan is *blocked*: each step consumes ``block_size`` points. One
fused distance+classification pass (``kernels.ops.center_precheck``) plus a
matroid-specific precheck classifies every point in the block as a no-op
(within threshold of an existing center AND its HANDLE would not add a
delegate) or as active; runs of no-ops are consumed with O(1) masked
updates and only active points — center opens, delegate adds, restructures,
the first two stream points, and anything within the distance kernel's
error margin of a decision boundary — replay the exact per-point step.
``block_size=1`` recovers the original per-point scan; both produce
bit-identical states (asserted by the equivalence/property tests).

The per-point step itself is *branchless*: every decision (open a center,
add a delegate, shrink, merge a dead center's delegate) is computed as a
mask and applied as a dense ``jnp.where``-selected update instead of a
``lax.cond`` ladder. Under ``vmap``/``shard_map`` a batched ``lax.cond``
lowers to select-both-branches, so the historical cond ladder made every
shard pay every branch of every step; the masked form pays each update
exactly once. The rare *expensive* branches (restructure merges) stay real
branches via ``_cond_once`` — a single-trip ``lax.while_loop``, which vmap
keeps conditional (zero trips when no lane triggers). The historical
cond-ladder step is retained as ``step_impl="reference"`` — the bit-exact
Alg.-2 semantics the branchless scan is defined by and tested against
(tests/test_branchless_scan.py).

Sharded ingestion has two drives over the same per-shard scan:

* ``ingest_batch_sharded`` — ``jit(vmap)`` over a leading shard axis
  (single-device; the branchless step is what makes this fast);
* ``ingest_batch_sharded_mapped`` — ``shard_map`` over a 1-D device mesh
  (per-device shard groups run as independent programs, vmapping only the
  shards local to each device).

Per §3 composability (and the MapReduce formulation of arXiv:1605.05590),
shards build coresets independently and compose by union — see
``core/compose.py`` for the union/merge half and placement resolution.

State (all static shapes; TCAP centers, SLOT delegate slots per center):
  R          scalar estimate (diameter for Alg. 2; radius for the variant)
  x1         first stream point (Alg. 2's anchor for the diameter estimate)
  centers    f32[TCAP, d], cvalid bool[TCAP]
  del_*      delegate buffers per center: points f32[TCAP, SLOT, d],
             cats int32[TCAP, SLOT, gamma], valid bool[TCAP, SLOT],
             src int32[TCAP, SLOT]

Per point: nearest center; if farther than the new-center threshold, open a
center (the point is its own first delegate — Alg. 2); else HANDLE(x, z).
HANDLE is matroid-specific and matches Alg. 2 case-by-case:
  partition    add iff |D_z| < k and cat-count < cap (D_z stays independent)
  uniform      add iff |D_z| < k
  transversal  add iff some category of x has < k delegates; then try the
               shrink step with a *greedy* matching witness (a greedy size-k
               matching proves an independent size-k subset exists; sound,
               possibly later than the paper's exact check — DESIGN.md §8)
Restructuring merges dropped centers' delegates into their nearest survivor
via the same HANDLE (Alg. 2's merge loop).

General matroids need a host oracle => use ``stream_coreset_host`` (plain
python loop; streaming is single-machine in the paper anyway).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .coreset import Coreset
from .matroid import MatroidSpec

_BIG = jnp.float32(jnp.finfo(jnp.float32).max)

STEP_IMPLS = ("branchless", "reference")


class StreamState(NamedTuple):
    R: jnp.ndarray
    x1: jnp.ndarray  # (d,)
    n_seen: jnp.ndarray  # int32, number of (valid) points consumed
    centers: jnp.ndarray  # (TCAP, d)
    cvalid: jnp.ndarray  # (TCAP,)
    dp: jnp.ndarray  # (TCAP, SLOT, d)
    dc: jnp.ndarray  # (TCAP, SLOT, gamma)
    dv: jnp.ndarray  # (TCAP, SLOT)
    ds: jnp.ndarray  # (TCAP, SLOT)
    overflow: jnp.ndarray  # int32: forced-discard count (transversal cap)


def _dists_to_centers(x, centers, cvalid):
    diff = centers - x[None, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    d = jnp.sqrt(jnp.maximum(d2, 0.0))
    return jnp.where(cvalid, d, _BIG)


def _cond_once(pred, fn, st):
    """``lax.cond(pred, fn, id)`` that stays a *real* branch under vmap.

    A batched ``lax.cond`` lowers to select-both-branches; a batched
    ``lax.while_loop`` executes its body only while some lane's predicate
    holds (with per-lane masking of the results). Wrapping a rarely-taken,
    expensive branch in a single-trip while_loop therefore keeps its skip
    under vmap — steps where no lane triggers pay nothing — while staying
    bit-identical to the cond form.
    """

    def body(carry):
        s, flag = carry
        return fn(s), jnp.zeros_like(flag)

    out, _ = jax.lax.while_loop(lambda c: c[1], body, (st, pred))
    return out


# --------------------------------------------------------------------------
# branchless masked primitives (the default scan)
# --------------------------------------------------------------------------


def _open_center_masked(st: StreamState, x, xc, xsrc, enable) -> StreamState:
    """Open a center at the first free slot iff ``enable``; otherwise every
    write puts the existing value back (a bit-exact no-op)."""
    slot = jnp.argmin(st.cvalid)  # first invalid center (all valid -> 0)
    return st._replace(
        centers=st.centers.at[slot].set(
            jnp.where(enable, x, st.centers[slot])
        ),
        cvalid=st.cvalid.at[slot].set(st.cvalid[slot] | enable),
        dp=st.dp.at[slot, 0].set(jnp.where(enable, x, st.dp[slot, 0])),
        dc=st.dc.at[slot, 0].set(jnp.where(enable, xc, st.dc[slot, 0])),
        dv=st.dv.at[slot, 0].set(st.dv[slot, 0] | enable),
        ds=st.ds.at[slot, 0].set(jnp.where(enable, xsrc, st.ds[slot, 0])),
    )


def _handle_masked(
    spec: MatroidSpec, k: int, caps, st: StreamState, z, x, xc, xsrc, enable
) -> tuple[StreamState, jnp.ndarray]:
    """Alg. 2 HANDLE(x, z, D_z) as masked dense updates.

    The add decision is computed unconditionally (cheap gathers/reductions
    over one center's slot buffer); the *write pass* — and, for
    transversal, the greedy-matching shrink that follows a successful add —
    runs under a ``_cond_once`` guard, so a rejected or disabled HANDLE
    costs no buffer traffic even under vmap. Executed writes are ``where``-
    masked per field, which keeps lanes that didn't trigger bit-exact.
    Returns ``(state, add)`` — ``add`` is the did-anything-change bit the
    blocked scan uses to decide precheck staleness.
    """
    slots_v = st.dv[z]  # (SLOT,)
    cnt = jnp.sum(slots_v.astype(jnp.int32))
    free_slot = jnp.argmin(slots_v)  # first False (all True -> 0, guarded)
    has_room = ~jnp.all(slots_v)

    if spec.kind == "uniform":
        add = cnt < k
        forced = jnp.int32(0)
    elif spec.kind == "partition":
        c = xc[0]
        same = slots_v & (st.dc[z, :, 0] == c)
        add = (cnt < k) & (jnp.sum(same.astype(jnp.int32)) < caps[c])
        forced = jnp.int32(0)
    elif spec.kind == "transversal":
        # count of delegates holding each category of x
        match = (st.dc[z][:, :, None] == xc[None, None, :]) & (
            xc[None, None, :] >= 0
        )  # (SLOT, gamma, gamma_x)
        holds = jnp.any(match, axis=1) & slots_v[:, None]  # (SLOT, gamma_x)
        cnts = jnp.sum(holds.astype(jnp.int32), axis=0)  # (gamma_x,)
        short = (cnts < k) & (xc >= 0)
        want = jnp.any(short)
        forced = (want & ~has_room & enable).astype(jnp.int32)
        add = want
    else:  # pragma: no cover
        raise ValueError(f"jit HANDLE not defined for {spec.kind!r}")

    add = add & has_room & enable
    st = st._replace(overflow=st.overflow + forced)

    def apply_add(st: StreamState) -> StreamState:
        st = st._replace(
            dp=st.dp.at[z, free_slot].set(
                jnp.where(add, x, st.dp[z, free_slot])
            ),
            dc=st.dc.at[z, free_slot].set(
                jnp.where(add, xc, st.dc[z, free_slot])
            ),
            dv=st.dv.at[z, free_slot].set(st.dv[z, free_slot] | add),
            ds=st.ds.at[z, free_slot].set(
                jnp.where(add, xsrc, st.ds[z, free_slot])
            ),
        )
        if spec.kind == "transversal":
            # masked shrink: a greedy matching covering k slots is a
            # witnessed independent size-k subset — keep exactly those
            # slots (post-add buffers, like the historical cond'd _shrink)
            from .solvers.matching import greedy_matching_slots

            slots_v2 = st.dv[z]
            _used, matched = greedy_matching_slots(
                st.dc[z], slots_v2, spec.num_categories
            )
            size = jnp.sum(matched.astype(jnp.int32))
            do = add & (size >= k)
            st = st._replace(
                dv=st.dv.at[z].set(
                    jnp.where(do, matched & slots_v2, slots_v2)
                )
            )
        return st

    return _cond_once(add, apply_add, st), add


def _merge_delegates(spec, k, caps, st: StreamState, dead_mask):
    """Alg. 2 restructure merge: delegates of dropped centers are HANDLE'd
    into their nearest surviving center.

    The tcap*slot fori_loop runs only when some center actually died — the
    ``_cond_once`` guard keeps that skip real even under vmap (a filter pass
    that keeps every center must not pay the merge loop on the scan's
    steady-state steps). The loop body itself is branchless: distance +
    masked HANDLE per slot."""
    tcap, slot_n = st.dv.shape

    def per_slot(i, st):
        ci, si = i // slot_n, i % slot_n
        en = dead_mask[ci] & st.dv[ci, si]
        x = st.dp[ci, si]
        d = _dists_to_centers(x, st.centers, st.cvalid)
        z = jnp.argmin(d)
        st, _add = _handle_masked(
            spec, k, caps, st, z, x, st.dc[ci, si], st.ds[ci, si], en
        )
        return st

    def run_merge(st: StreamState) -> StreamState:
        st = jax.lax.fori_loop(0, tcap * slot_n, per_slot, st)
        # clear dropped centers' own buffers
        return st._replace(dv=st.dv & ~dead_mask[:, None])

    return _cond_once(jnp.any(dead_mask), run_merge, st)


# --------------------------------------------------------------------------
# reference cond-ladder primitives (``step_impl="reference"``)
#
# The historical per-point step, kept verbatim: nested lax.cond dispatch on
# (first | second | general), cond'd HANDLE add + shrink, cond'd merge loop.
# This is the bit-exact Alg.-2 semantics the branchless step is defined by;
# tests/test_branchless_scan.py asserts field-for-field state identity
# between the two across matroid kinds, variants, block sizes and shards.
# --------------------------------------------------------------------------


def _handle_ref(spec: MatroidSpec, k: int, caps, st: StreamState, z, x, xc,
                xsrc):
    """Alg. 2 HANDLE(x, z, D_z). Returns updated state (+overflow count)."""
    slots_v = st.dv[z]  # (SLOT,)
    cnt = jnp.sum(slots_v.astype(jnp.int32))
    free_slot = jnp.argmin(slots_v)  # first False (all True -> 0, guarded)
    has_room = ~jnp.all(slots_v)

    if spec.kind == "uniform":
        add = cnt < k
        forced = jnp.int32(0)
    elif spec.kind == "partition":
        c = xc[0]
        same = slots_v & (st.dc[z, :, 0] == c)
        add = (cnt < k) & (jnp.sum(same.astype(jnp.int32)) < caps[c])
        forced = jnp.int32(0)
    elif spec.kind == "transversal":
        match = (st.dc[z][:, :, None] == xc[None, None, :]) & (
            xc[None, None, :] >= 0
        )  # (SLOT, gamma, gamma_x)
        holds = jnp.any(match, axis=1) & slots_v[:, None]  # (SLOT, gamma_x)
        cnts = jnp.sum(holds.astype(jnp.int32), axis=0)  # (gamma_x,)
        short = (cnts < k) & (xc >= 0)
        want = jnp.any(short)
        add = want & has_room
        forced = (want & ~has_room).astype(jnp.int32)
    else:  # pragma: no cover
        raise ValueError(f"jit HANDLE not defined for {spec.kind!r}")

    add = add & has_room

    def do_add(st: StreamState) -> StreamState:
        return st._replace(
            dp=st.dp.at[z, free_slot].set(x),
            dc=st.dc.at[z, free_slot].set(xc),
            dv=st.dv.at[z, free_slot].set(True),
            ds=st.ds.at[z, free_slot].set(xsrc),
        )

    st = jax.lax.cond(add, do_add, lambda s: s, st)
    st = st._replace(overflow=st.overflow + forced)

    if spec.kind == "transversal":
        st = jax.lax.cond(
            add, lambda s: _shrink_ref(spec, k, s, z), lambda s: s, st
        )
    return st


def _shrink_ref(spec: MatroidSpec, k: int, st: StreamState, z):
    """Greedy-matching shrink: if a greedy matching of D_z covers k slots,
    keep exactly those slots (a witnessed independent set of size k)."""
    from .solvers.matching import greedy_matching_slots

    slots_v = st.dv[z]
    _used, matched = greedy_matching_slots(
        st.dc[z], slots_v, spec.num_categories
    )
    size = jnp.sum(matched.astype(jnp.int32))

    def do_shrink(st: StreamState) -> StreamState:
        return st._replace(dv=st.dv.at[z].set(matched & slots_v))

    return jax.lax.cond(size >= k, do_shrink, lambda s: s, st)


def _merge_delegates_ref(spec, k, caps, st: StreamState, dead_mask):
    """The cond-ladder restructure merge (reference semantics)."""
    tcap, slot_n = st.dv.shape

    def per_slot(i, st):
        ci, si = i // slot_n, i % slot_n
        is_live_del = dead_mask[ci] & st.dv[ci, si]

        def do(st: StreamState) -> StreamState:
            x = st.dp[ci, si]
            d = _dists_to_centers(x, st.centers, st.cvalid)
            z = jnp.argmin(d)
            return _handle_ref(
                spec, k, caps, st, z, x, st.dc[ci, si], st.ds[ci, si]
            )

        return jax.lax.cond(is_live_del, do, lambda s: s, st)

    def run_merge(st: StreamState) -> StreamState:
        st = jax.lax.fori_loop(0, tcap * slot_n, per_slot, st)
        return st._replace(dv=st.dv & ~dead_mask[:, None])

    return jax.lax.cond(jnp.any(dead_mask), run_merge, lambda s: s, st)


def _filter_centers(st: StreamState, thr):
    """Greedy maximal subset of centers with pairwise distance > thr."""
    c = st.centers
    d2 = jnp.sum((c[:, None, :] - c[None, :, :]) ** 2, axis=-1)
    d = jnp.sqrt(jnp.maximum(d2, 0.0))
    tcap = c.shape[0]

    def body(i, keep):
        near_kept = jnp.any(keep & st.cvalid & (d[i] <= thr) &
                            (jnp.arange(tcap) < i))
        ki = st.cvalid[i] & ~near_kept
        return keep.at[i].set(ki)

    keep = jax.lax.fori_loop(0, tcap, body, jnp.zeros((tcap,), bool))
    return keep


def default_slot_cap(spec: MatroidSpec, k: int) -> int:
    """Static per-center delegate capacity (Alg. 2 size bounds)."""
    if spec.kind in ("uniform", "partition"):
        return k
    return max(spec.gamma, 1) * k * k


def init_stream_state(
    d: int,
    gamma: int,
    spec: MatroidSpec,
    k: int,
    tau: int,
    *,
    slot_cap: Optional[int] = None,
) -> StreamState:
    """Empty resumable scan state (the ingestion API's starting point).

    The returned state is a pure pytree of static-shape buffers: feed it to
    ``ingest_batch`` any number of times, snapshot with ``snapshot_coreset``.

    ``tau >= 2``: the scan unconditionally opens centers for the first two
    stream points (Alg. 2's anchors) before any restructure can run, so a
    smaller tau could enter a general step already over budget — a state
    the radius-variant restructure bookkeeping (and the blocked scan's
    "an over-tau count only follows an open" staleness invariant) is
    allowed to assume impossible.
    """
    if tau < 2:
        raise ValueError(f"tau must be >= 2, got {tau}")
    tcap = tau + 1
    if slot_cap is None:
        slot_cap = default_slot_cap(spec, k)
    return StreamState(
        R=jnp.float32(0.0),
        x1=jnp.zeros((d,), jnp.float32),
        n_seen=jnp.int32(0),
        centers=jnp.zeros((tcap, d), jnp.float32),
        cvalid=jnp.zeros((tcap,), bool),
        dp=jnp.zeros((tcap, slot_cap, d), jnp.float32),
        dc=jnp.full((tcap, slot_cap, gamma), -1, jnp.int32),
        dv=jnp.zeros((tcap, slot_cap), bool),
        ds=jnp.full((tcap, slot_cap), -1, jnp.int32),
        overflow=jnp.int32(0),
    )


def _epoch_stats_impl(st: StreamState):
    """Device-side epoch statistics of a scan state: ``(count, h1, h2)``.

    The coreset is determined by which ``(center, slot)`` cells are live and
    which stream row each holds, i.e. by ``(dv & cvalid, ds)``. Instead of
    pulling those buffers to the host and hashing them per ingest (the
    historical fingerprint — an O(buffers) host sync on the serving hot
    path), this reduces them *on device* to three scalars: the live-cell
    count (from the same per-center count tables the blocked precheck
    uses) plus two independent position-mixed uint32 checksums, so the
    epoch decision ("did the coreset change?") costs one O(1) host pull.
    Positions enter each sum through distinct odd multipliers, so moving a
    delegate between cells — or swapping two — changes the value; two
    checksums with different mixes make an accidental collision of a real
    change astronomically unlikely. Accepts a single state or a stacked
    per-shard state (the reductions flatten every leading axis).
    """
    with jax.named_scope("dmmc/epoch_stats"):
        valid = st.dv & st.cvalid[..., None]
        vz = valid.reshape(-1)
        src = jnp.where(vz, st.ds.reshape(-1).astype(jnp.uint32) + 1, 0)
        pos = jnp.arange(vz.shape[0], dtype=jnp.uint32)
        count = jnp.sum(jnp.sum(valid, axis=-1, dtype=jnp.int32))
        h1 = jnp.sum(
            src * (pos * jnp.uint32(0x9E3779B1) | 1), dtype=jnp.uint32
        )
        h2 = jnp.sum(
            (src ^ (pos * jnp.uint32(0x85EBCA6B))) * jnp.uint32(0x27D4EB2F),
            dtype=jnp.uint32,
        )
        return count, h1, h2


# Not donated: it must observe the live serving state without consuming it
# (the ingest entry points donate; this one only reads).
epoch_stats = jax.jit(_epoch_stats_impl)


def epoch_fingerprint(st: StreamState) -> tuple[int, int]:
    """Host ``(fingerprint, coreset_size)`` of a scan state via one O(1)
    device sync — the epoch-snapshot decision point of the serving runtime
    (``serve.diversity.StreamRuntime``): ingestion calls this per batch and
    publishes a new epoch only when the fingerprint moved."""
    count, h1, h2 = jax.device_get(epoch_stats(st))
    return hash((int(count), int(h1), int(h2))), int(count)


def state_to_arrays(st: StreamState) -> dict:
    """Serialize one ``StreamState`` to plain host arrays, field-keyed.

    The scan is a pure fold, so this dict — float32/int32/bool buffers
    pulled off the device — IS the resumable stream: round-tripping
    through ``state_from_arrays`` and resuming ingestion is bit-identical
    to never having serialized (pinned by the checkpoint/restore parity
    suite). Works on single and stacked (leading shard axis) states
    alike; the serving checkpoint layer (``serve.diversity.checkpoint``)
    handles the per-shard list of the pipeline placement.
    """
    return {f: np.asarray(getattr(st, f)) for f in StreamState._fields}


def state_from_arrays(arrays) -> StreamState:
    """Rebuild a device ``StreamState`` from ``state_to_arrays`` output
    (dtypes preserved exactly; missing fields raise ``KeyError``)."""
    return StreamState(
        **{f: jnp.asarray(np.asarray(arrays[f]))
           for f in StreamState._fields}
    )


def snapshot_coreset(st: StreamState) -> Coreset:
    """Assemble the current coreset from the delegate buffers (jit-safe)."""
    tcap, slot_cap, d = st.dp.shape
    gamma = st.dc.shape[2]
    flat_valid = st.dv.reshape(-1) & jnp.repeat(st.cvalid, slot_cap)
    return Coreset(
        points=st.dp.reshape(-1, d),
        cats=st.dc.reshape(-1, gamma),
        valid=flat_valid,
        src_idx=jnp.where(flat_valid, st.ds.reshape(-1), -1),
    )


def _make_step_branchless(spec: MatroidSpec, k: int, tau: int, caps_arr,
                          variant: str, eps: float, c_const: int):
    """Branchless masked-update per-point step (the default scan step).

    Every per-point decision becomes a mask over one dense update pass:
    distances/argmin are computed once, the (first | second | open | handle)
    cases are disjoint enables over masked writes, and ``n_seen`` advances
    by the validity bit. Only the restructure merges — rare and genuinely
    expensive — remain real branches, via ``_cond_once`` (vmap-skippable).
    Bit-identical to ``_make_step_reference`` (parity suite) because every
    masked-off write puts the existing value back.
    """

    def restructure_radius(st: StreamState) -> StreamState:
        """tau-variant: while #centers > tau: R *= 2; filter; merge."""

        def cond(st):
            return jnp.sum(st.cvalid.astype(jnp.int32)) > tau

        def body(st):
            R = st.R * 2.0
            st = st._replace(R=R)
            keep = _filter_centers(st, R)
            dead = st.cvalid & ~keep
            st = st._replace(cvalid=keep)
            return _merge_delegates(spec, k, caps_arr, st, dead)

        return jax.lax.while_loop(cond, body, st)

    def restructure_diameter(st: StreamState) -> StreamState:
        """Alg. 2: after R update, filter at eps*R/(ck) and merge."""
        thr = jnp.float32(eps) * st.R / (c_const * k)
        keep = _filter_centers(st, thr)
        dead = st.cvalid & ~keep
        st = st._replace(cvalid=keep)
        return _merge_delegates(spec, k, caps_arr, st, dead)

    def step(st: StreamState, inp):
        x, xc, xsrc, v = inp
        t = st.n_seen
        is_first = v & (t == 0)
        is_second = v & (t == 1)
        is_general = v & (t >= 2)

        # one distance pass against the pre-step centers (first/second lanes
        # read garbage here; their enables mask every use of it)
        dists = _dists_to_centers(x, st.centers, st.cvalid)
        z = jnp.argmin(dists)
        dmin = dists[z]
        if variant == "diameter":
            thr_new = 2.0 * eps * st.R / (c_const * k)
        else:
            thr_new = 2.0 * st.R
        opens = is_first | is_second | (is_general & (dmin > thr_new))
        handles = is_general & ~(dmin > thr_new)

        st = _cond_once(
            opens, lambda s: _open_center_masked(s, x, xc, xsrc, opens), st
        )
        st, added = _handle_masked(
            spec, k, caps_arr, st, z, x, xc, xsrc, handles
        )

        # first/second bookkeeping: anchor + initial estimate
        r0 = jnp.sqrt(jnp.maximum(jnp.sum((x - st.x1) ** 2), 0.0))
        R2 = r0 if variant == "diameter" else r0 / 2.0
        st = st._replace(
            R=jnp.where(is_second, jnp.maximum(R2, 1e-30), st.R),
            x1=jnp.where(is_first, x, st.x1),
        )

        if variant == "diameter":
            d1 = jnp.sqrt(jnp.maximum(jnp.sum((x - st.x1) ** 2), 0.0))
            trigger = is_general & (d1 > 2.0 * st.R)

            def upd(st):
                st = st._replace(R=d1)
                return restructure_diameter(st)

            st = _cond_once(trigger, upd, st)
            changed = opens | added | trigger
        else:
            need = is_general & (
                jnp.sum(st.cvalid.astype(jnp.int32)) > tau
            )
            st = _cond_once(need, restructure_radius, st)
            # an over-tau center count only ever follows an open this step,
            # so `opens` subsumes `need` in the changed bit
            changed = opens | added
        # `changed` is the precheck-staleness bit: True iff any field the
        # block precheck reads (centers/cvalid/dv/dc/R/x1) may have been
        # written. n_seen/overflow always advance but are not precheck
        # inputs.
        return st._replace(n_seen=t + v.astype(jnp.int32)), changed

    return step


def _make_step_reference(spec: MatroidSpec, k: int, tau: int, caps_arr,
                         variant: str, eps: float, c_const: int):
    """The historical cond-ladder per-point Alg.-2 scan step (the bit-exact
    reference semantics the branchless step is defined by)."""

    def open_center(st: StreamState, x, xc, xsrc) -> StreamState:
        slot = jnp.argmin(st.cvalid)
        return st._replace(
            centers=st.centers.at[slot].set(x),
            cvalid=st.cvalid.at[slot].set(True),
            dp=st.dp.at[slot, 0].set(x),
            dc=st.dc.at[slot, 0].set(xc),
            dv=st.dv.at[slot, 0].set(True),
            ds=st.ds.at[slot, 0].set(xsrc),
        )

    def restructure_radius(st: StreamState) -> StreamState:
        """tau-variant: while #centers > tau: R *= 2; filter; merge."""

        def cond(st):
            return jnp.sum(st.cvalid.astype(jnp.int32)) > tau

        def body(st):
            R = st.R * 2.0
            st = st._replace(R=R)
            keep = _filter_centers(st, R)
            dead = st.cvalid & ~keep
            st = st._replace(cvalid=keep)
            return _merge_delegates_ref(spec, k, caps_arr, st, dead)

        return jax.lax.while_loop(cond, body, st)

    def restructure_diameter(st: StreamState) -> StreamState:
        """Alg. 2: after R update, filter at eps*R/(ck) and merge."""
        thr = jnp.float32(eps) * st.R / (c_const * k)
        keep = _filter_centers(st, thr)
        dead = st.cvalid & ~keep
        st = st._replace(cvalid=keep)
        return _merge_delegates_ref(spec, k, caps_arr, st, dead)

    def step(st: StreamState, inp):
        x, xc, xsrc, v = inp
        t = st.n_seen

        def skip(st):
            return st

        def first(st: StreamState) -> StreamState:
            st = open_center(st, x, xc, xsrc)
            return st._replace(x1=x, n_seen=t + 1)

        def second(st: StreamState) -> StreamState:
            r0 = jnp.sqrt(
                jnp.maximum(jnp.sum((x - st.x1) ** 2), 0.0)
            )
            st = open_center(st, x, xc, xsrc)
            R = r0 if variant == "diameter" else r0 / 2.0
            return st._replace(R=jnp.maximum(R, 1e-30), n_seen=t + 1)

        def general(st: StreamState) -> StreamState:
            dists = _dists_to_centers(x, st.centers, st.cvalid)
            z = jnp.argmin(dists)
            dmin = dists[z]
            if variant == "diameter":
                thr_new = 2.0 * eps * st.R / (c_const * k)
            else:
                thr_new = 2.0 * st.R

            def as_new(st):
                return open_center(st, x, xc, xsrc)

            def as_handle(st):
                return _handle_ref(spec, k, caps_arr, st, z, x, xc, xsrc)

            st = jax.lax.cond(dmin > thr_new, as_new, as_handle, st)

            if variant == "diameter":
                d1 = jnp.sqrt(jnp.maximum(jnp.sum((x - st.x1) ** 2), 0.0))

                def upd(st):
                    st = st._replace(R=d1)
                    return restructure_diameter(st)

                st = jax.lax.cond(d1 > 2.0 * st.R, upd, lambda s: s, st)
            else:
                st = jax.lax.cond(
                    jnp.sum(st.cvalid.astype(jnp.int32)) > tau,
                    restructure_radius,
                    lambda s: s,
                    st,
                )
            return st._replace(n_seen=t + 1)

        branch = jnp.where(t == 0, 0, jnp.where(t == 1, 1, 2))
        st = jax.lax.cond(
            v,
            lambda st: jax.lax.switch(branch, [first, second, general], st),
            skip,
            st,
        )
        # conservative staleness bit: the reference impl always reports
        # "maybe changed", so the blocked scan re-prechecks every iteration
        # (the historical behavior)
        return st, jnp.bool_(True)

    return step


def _make_step(spec: MatroidSpec, k: int, tau: int, caps_arr, variant: str,
               eps: float, c_const: int, step_impl: str = "branchless"):
    """Build the per-point Alg.-2 scan step (``branchless`` masked-update
    default, or the historical ``reference`` cond ladder)."""
    if step_impl not in STEP_IMPLS:
        raise ValueError(
            f"step_impl must be one of {STEP_IMPLS}, got {step_impl!r}"
        )
    make = (
        _make_step_branchless
        if step_impl == "branchless"
        else _make_step_reference
    )
    return make(spec, k, tau, caps_arr, variant, eps, c_const)


def _block_precheck(spec: MatroidSpec, k: int, caps_arr, variant: str,
                    eps: float, c_const: int, st: StreamState,
                    xb, xcb, vb):
    """Vectorized would-this-point-change-state test for a block of points,
    evaluated against the *current* state.

    Returns (active bool[B], forced int32[B]). A point is active iff the
    per-point step would do anything beyond incrementing ``n_seen`` (and, for
    transversal, ``overflow``): open a center, add a delegate (incl. the
    shrink that follows), trigger the diameter-variant R update, or fall
    within the distance kernel's error margin of any of those decision
    boundaries. Inactive valid points are exact no-ops whose only effect is
    ``n_seen += 1`` and ``overflow += forced`` — the invariant the blocked
    scan's bulk-skip relies on (state-unchanged induction along the block).

    The distance + top-3-nearest classification is one fused op
    (``kernels.ops.center_precheck``: Pallas panel-matmul kernel on TPU,
    matmul-form jnp on CPU, the exact broadcast oracle under ``ref``), and
    the two candidate centers it returns are *exact-refined* here: a
    (B, 2, d) gather recomputes their distances with the per-point
    arithmetic, so the nearest-center choice and the open threshold are
    decided exactly and only two cases still fall back to the sequential
    replay — an exact tie between the two candidates (``jnp.argmin``'s
    first-index rule needs the full buffer order) and a third candidate
    within the matmul error margin of the refined minimum (the candidate
    pair might then not contain the true nearest).
    """
    from ..kernels import ops as _ops

    dmin_e, z1, _second_e, z2, third_e, margin = _ops.center_precheck(
        xb, st.centers, st.cvalid
    )
    d1e = jnp.sqrt(
        jnp.maximum(jnp.sum((st.centers[z1] - xb) ** 2, axis=-1), 0.0)
    )
    d2e = jnp.sqrt(
        jnp.maximum(jnp.sum((st.centers[z2] - xb) ** 2, axis=-1), 0.0)
    )
    d1e = jnp.where(st.cvalid[z1], d1e, _BIG)
    d2e = jnp.where(st.cvalid[z2], d2e, _BIG)
    z = jnp.where(d2e < d1e, z2, z1)
    dmin = jnp.minimum(d1e, d2e)
    # sequential-fallback cases: exact candidate tie, or the third-nearest
    # estimate within the error margin of the estimated minimum
    tie = (d1e == d2e) | ((third_e - dmin_e) <= 2.0 * margin)

    if variant == "diameter":
        thr_new = 2.0 * eps * st.R / (c_const * k)
    else:
        thr_new = 2.0 * st.R
    opens = dmin > thr_new

    # HANDLE classification via per-center count tables: O(T * SLOT) once
    # per block + O(B) scalar gathers, instead of gathering every row's
    # (SLOT[, gamma]) delegate buffers. Counts are integers, so the add
    # decisions are exactly the per-row sums the scan step computes.
    cnt_t = jnp.sum(st.dv.astype(jnp.int32), axis=1)  # (T,)
    full_t = jnp.all(st.dv, axis=1)  # (T,)
    cnt = cnt_t[z]
    has_room = ~full_t[z]
    # Rows whose labels fall outside the table range cannot be classified
    # by the count tables (a gather would clamp/wrap where the per-point
    # step compares `dc == c` exactly) — flag them active so the exact
    # replay decides, preserving bit-identity for arbitrary label input.
    if spec.kind == "uniform":
        add = cnt < k
        forced = jnp.zeros(xb.shape[0], jnp.int32)
        oob = jnp.zeros(xb.shape[0], bool)
    elif spec.kind == "partition":
        c = xcb[:, 0]
        h = max(spec.num_categories, 1)
        oob = (c < 0) | (c >= h)
        same_t = jnp.sum(
            (
                (st.dc[:, :, 0, None] == jnp.arange(h)[None, None, :])
                & st.dv[:, :, None]
            ).astype(jnp.int32),
            axis=1,
        )  # (T, h): delegates of center t in category c
        cs = jnp.clip(c, 0, h - 1)
        add = (cnt < k) & (same_t[z, cs] < caps_arr[cs])
        forced = jnp.zeros(xb.shape[0], jnp.int32)
    elif spec.kind == "transversal":
        h = max(spec.num_categories, 1)
        oob = jnp.any(xcb >= h, axis=1)  # -1 padding is masked below
        holds_t = jnp.any(
            st.dc[:, :, :, None] == jnp.arange(h)[None, None, None, :],
            axis=2,
        ) & st.dv[:, :, None]  # (T, SLOT, h): slot holds category
        cnt_th = jnp.sum(holds_t.astype(jnp.int32), axis=1)  # (T, h)
        cnts = cnt_th[z[:, None], jnp.clip(xcb, 0, h - 1)]  # (B, gamma_x)
        short = (cnts < k) & (xcb >= 0)
        want = jnp.any(short, axis=1)
        add = want & has_room
        forced = (want & ~has_room & ~oob).astype(jnp.int32)
    else:  # pragma: no cover
        raise ValueError(f"blocked scan not defined for {spec.kind!r}")
    add = add & has_room

    active = opens | add | tie | oob
    if variant == "diameter":
        # d1 is the per-point arithmetic itself (row-wise diff/square/sum),
        # so the R-update trigger is decided exactly — no margin needed
        d1 = jnp.sqrt(
            jnp.maximum(jnp.sum((xb - st.x1[None, :]) ** 2, axis=-1), 0.0)
        )
        active = active | (d1 > 2.0 * st.R)
    return active & vb, forced


def _blocked_scan(step, spec: MatroidSpec, k: int, caps_arr, variant: str,
                  eps: float, c_const: int, st0: StreamState,
                  points, cats, src, valid, block_size: int) -> StreamState:
    """Scan B points per step: one vectorized distance/precheck pass decides
    which points could change state; runs of no-op points are consumed in
    O(1) masked updates and only the (rare, in steady state) active points
    replay the exact per-point step — bit-identical to the per-point scan."""
    n, d = points.shape
    B = block_size
    pad = -n % B
    if pad:
        points = jnp.concatenate([points, jnp.zeros((pad, d), points.dtype)])
        cats = jnp.concatenate(
            [cats, jnp.full((pad, cats.shape[1]), -1, cats.dtype)]
        )
        src = jnp.concatenate([src, jnp.full((pad,), -1, jnp.int32)])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), bool)])
    nb = points.shape[0] // B
    Pb = points.reshape(nb, B, d)
    Cb = cats.reshape(nb, B, -1)
    Sb = src.reshape(nb, B)
    Vb = valid.reshape(nb, B)
    idx = jnp.arange(B, dtype=jnp.int32)

    def block_step(st: StreamState, inp):
        xb, xcb, srcb, vb = inp

        # one precheck against the block-entry state decides the whole
        # block when nothing is active (the steady-state case): the loop
        # below — whose batched-while carry select would copy every state
        # buffer per iteration under vmap — is entered only when some
        # point actually needs a sequential replay
        with jax.named_scope("dmmc/precheck"):
            active0, forced0 = _block_precheck(
                spec, k, caps_arr, variant, eps, c_const, st, xb, xcb, vb
            )
        excl0 = jnp.cumsum(vb.astype(jnp.int32)) - vb.astype(jnp.int32)
        any_act = jnp.any(active0 | (vb & (st.n_seen + excl0 < 2)))
        nv = jnp.sum(vb.astype(jnp.int32))
        fo = jnp.sum(jnp.where(vb, forced0, 0))
        st = st._replace(
            n_seen=st.n_seen + jnp.where(any_act, 0, nv),
            overflow=st.overflow + jnp.where(any_act, 0, fo),
        )

        def cond(carry):
            return carry[1] < B

        def body(carry):
            st, i, active, forced, dirty = carry

            # the precheck is a pure function of (centers, cvalid, dv, dc,
            # R, x1); replaying a point that changed none of them (a
            # margin-fallback no-op) leaves the cached classification
            # bit-identical, so only `dirty` iterations recompute it
            def recompute(_):
                return _block_precheck(
                    spec, k, caps_arr, variant, eps, c_const, st, xb, xcb,
                    vb,
                )

            active, forced = _cond_once(dirty, recompute, (active, forced))
            rem = idx >= i
            # the first two (valid) stream points take special branches
            vrem = vb & rem
            excl = jnp.cumsum(vrem.astype(jnp.int32)) - vrem.astype(jnp.int32)
            act = (active | (vrem & (st.n_seen + excl < 2))) & rem
            f = jnp.where(jnp.any(act), jnp.argmax(act), B).astype(jnp.int32)
            skip = vrem & (idx < f)
            st = st._replace(
                n_seen=st.n_seen + jnp.sum(skip.astype(jnp.int32)),
                overflow=st.overflow + jnp.sum(jnp.where(skip, forced, 0)),
            )
            fs = jnp.minimum(f, B - 1)  # clamped gather; guarded by f < B

            def do_point(carry):
                st, _ = carry
                return step(st, (xb[fs], xcb[fs], srcb[fs], vb[fs]))

            # _cond_once, not lax.cond: under vmap a cond pays the replay
            # step on every block iteration of every shard; the single-trip
            # while skips it for real whenever no lane found an active point
            st, changed = _cond_once(
                f < B, do_point, (st, jnp.bool_(False))
            )
            return st, f + 1, active, forced, changed

        def run_block(st: StreamState) -> StreamState:
            # seeded with the hoisted precheck (dirty=False: the state has
            # not changed since it was computed)
            st, _, _, _, _ = jax.lax.while_loop(
                cond,
                body,
                (st, jnp.int32(0), active0, forced0, jnp.bool_(False)),
            )
            return st

        st = _cond_once(any_act, run_block, st)
        return st, None

    with jax.named_scope("dmmc/blocked_scan"):
        st, _ = jax.lax.scan(block_step, st0, (Pb, Cb, Sb, Vb))
    return st


def _ingest_core(st0: StreamState, points, cats, valid, src,
                 spec: MatroidSpec, caps_arr, k: int, tau: int,
                 variant: str, eps: float, c_const: int,
                 block_size: int, step_impl: str) -> StreamState:
    step = _make_step(spec, k, tau, caps_arr, variant, eps, c_const,
                      step_impl)
    valid = valid.astype(bool)
    if block_size <= 1:
        st, _ = jax.lax.scan(
            lambda s, inp: (step(s, inp)[0], None),
            st0, (points, cats, src, valid),
        )
        return st
    return _blocked_scan(
        step, spec, k, caps_arr, variant, eps, c_const,
        st0, points, cats, src, valid, block_size,
    )


def _ingest_batch_impl(
    st0: StreamState,
    points: jnp.ndarray,
    cats: jnp.ndarray,
    valid: jnp.ndarray,
    spec: MatroidSpec,
    caps: Optional[jnp.ndarray],
    k: int,
    tau: int,
    *,
    base_index: jnp.ndarray = 0,
    variant: str = "radius",
    eps: float = 0.5,
    c_const: int = 32,
    block_size: int = 128,
    step_impl: str = "branchless",
    src: Optional[jnp.ndarray] = None,
) -> StreamState:
    n, _ = points.shape
    caps_arr = caps if caps is not None else jnp.zeros((1,), jnp.int32)
    if src is None:
        src = jnp.asarray(base_index, jnp.int32) + jnp.arange(
            n, dtype=jnp.int32
        )
    else:
        src = jnp.asarray(src, jnp.int32)
    return _ingest_core(
        st0, points, cats, valid, src, spec, caps_arr, k, tau,
        variant, eps, c_const, block_size, step_impl,
    )


_INGEST_STATICS = (
    "spec", "k", "tau", "variant", "c_const", "block_size", "step_impl"
)

ingest_batch = functools.partial(
    jax.jit, static_argnames=_INGEST_STATICS
)(_ingest_batch_impl)

# donated variant for resume-in-place callers (state reassigned every call,
# e.g. the serving layer): XLA aliases the old state's buffers into the new
# state's, so a steady-state ingest stops paying a full state copy per call
# — the dominant fixed cost once the scan itself is branchless. The donated
# input is consumed: only use when the passed state is dropped on return.
ingest_batch_donated = functools.partial(
    jax.jit, static_argnames=_INGEST_STATICS, donate_argnums=(0,)
)(_ingest_batch_impl)

ingest_batch.__doc__ = _ingest_batch_impl.__doc__ = (
    """Resume the jit'd Alg.-2 scan over one batch of the stream.

    ``st0`` is ``init_stream_state(...)`` or the state returned by a previous
    ``ingest_batch`` call; ``base_index`` offsets the delegates' ``src_idx``
    so they stay global across batches. The scan branches on ``st.n_seen``,
    so resuming mid-stream is exact: the concatenation of batches yields
    bit-identical state to a single one-shot pass.

    ``block_size`` > 1 selects the blocked scan (B points per step; the
    vectorized precheck bulk-skips no-op points and replays only state-
    changing ones through the per-point step) — bit-identical to
    ``block_size=1`` by construction; the equivalence tests parameterize
    over both. ``step_impl`` selects the branchless masked-update step
    (default) or the historical cond-ladder reference, themselves
    bit-identical (tests/test_branchless_scan.py). ``ingest_batch_donated``
    is the same function with the input state donated (serving hot path).
    """
)


def init_sharded_states(
    num_shards: int,
    d: int,
    gamma: int,
    spec: MatroidSpec,
    k: int,
    tau: int,
    *,
    slot_cap: Optional[int] = None,
) -> StreamState:
    """Stacked pytree of ``num_shards`` empty stream states (leading shard
    axis on every leaf) — the carry for ``ingest_batch_sharded``."""
    st = init_stream_state(d, gamma, spec, k, tau, slot_cap=slot_cap)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (num_shards,) + x.shape), st
    )


def _ingest_batch_sharded_impl(
    sts: StreamState,  # stacked: every leaf has leading shard axis S
    points: jnp.ndarray,  # (S, m, d)
    cats: jnp.ndarray,  # (S, m, gamma)
    valid: jnp.ndarray,  # (S, m)
    src: jnp.ndarray,  # (S, m) global stream indices
    spec: MatroidSpec,
    caps: Optional[jnp.ndarray],
    k: int,
    tau: int,
    *,
    variant: str = "radius",
    eps: float = 0.5,
    c_const: int = 32,
    block_size: int = 128,
    step_impl: str = "branchless",
) -> StreamState:
    caps_arr = caps if caps is not None else jnp.zeros((1,), jnp.int32)

    def one(st, p, c, v, s):
        return _ingest_core(
            st, p, c, v, s, spec, caps_arr, k, tau,
            variant, eps, c_const, block_size, step_impl,
        )

    return jax.vmap(one)(sts, points, cats, valid.astype(bool), src)


ingest_batch_sharded = functools.partial(
    jax.jit, static_argnames=_INGEST_STATICS
)(_ingest_batch_sharded_impl)

# donated variant (see ingest_batch_donated): a stacked shard state is S
# full StreamStates, so the per-call output copy it avoids is S times larger
ingest_batch_sharded_donated = functools.partial(
    jax.jit, static_argnames=_INGEST_STATICS, donate_argnums=(0,)
)(_ingest_batch_sharded_impl)

ingest_batch_sharded.__doc__ = _ingest_batch_sharded_impl.__doc__ = (
    """vmapped blocked ingestion: every shard runs its own independent
    Alg.-2 scan (paper §3 / the MapReduce formulation: coresets of a
    partition compose by union). Per-shard results are bit-identical to
    running ``ingest_batch`` on that shard's sub-stream alone.

    This is the single-device drive; the branchless step is what makes it
    fast (a vmapped cond ladder pays select-both-branches on every step).
    With more than one device, ``ingest_batch_sharded_mapped`` runs the
    shard groups as per-device programs instead.
    """
)


PLACEMENTS = ("auto", "vmap", "shard_map", "pipeline")


def resolve_placement(placement: str, num_shards: int) -> str:
    """Resolve the sharded-ingest drive.

    ``vmap``       one batched program over row-granular round-robin shard
                   sub-streams (single-accelerator drive: one launch covers
                   all shards; the branchless step is what makes it cheap);
    ``shard_map``  per-device shard groups over a 1-D mesh (multi-device
                   accelerator drive: real branches, real parallelism, one
                   SPMD launch);
    ``pipeline``   batch-granular round-robin over independent per-shard
                   states pinned across devices — each ingest is the plain
                   blocked scan (identical executable to the unsharded
                   path, so sharding costs nothing on a host CPU), and
                   consecutive batches hit different states/devices so
                   async dispatch can overlap them.

    ``auto``: CPU backend -> ``pipeline`` (a host pays shard_map's
    per-call SPMD launch without an accelerator's gain, and vmap's lane
    overhead without its launch amortization); otherwise ``shard_map``
    when more than one device can take a whole shard, else ``vmap``.
    """
    if placement not in PLACEMENTS:
        raise ValueError(
            f"placement must be one of {PLACEMENTS}, got {placement!r}"
        )
    if placement != "auto":
        return placement
    if num_shards <= 1:
        return "vmap"
    if jax.default_backend() == "cpu":
        return "pipeline"
    return (
        "shard_map" if mesh_device_count(num_shards) > 1 else "vmap"
    )


def mesh_device_count(num_shards: int, n_devices: Optional[int] = None) -> int:
    """Largest device count <= n_devices that divides ``num_shards`` (each
    device must own an equal, whole number of shard states)."""
    if n_devices is None:
        n_devices = jax.device_count()
    nd = max(1, min(int(n_devices), int(num_shards)))
    while num_shards % nd:
        nd -= 1
    return nd


@functools.lru_cache(maxsize=None)
def _sharded_mapped_fn(nd: int, spec: MatroidSpec, k: int, tau: int,
                       variant: str, eps: float, c_const: int,
                       block_size: int, step_impl: str, donate: bool):
    """jit(shard_map(vmap(scan))) over a 1-D ``shards`` mesh of nd devices,
    cached per (mesh size, scan statics). Device list is process-stable, so
    caching on nd alone is sound."""
    from jax.sharding import PartitionSpec as P

    from ..compat import shard_map as _shard_map
    from ..launch.mesh import make_mesh

    mesh = make_mesh((nd,), ("shards",), devices=jax.devices()[:nd])
    psh = P("shards")

    def local(sts, p, c, v, s, caps_arr):
        def one(st, p1, c1, v1, s1):
            return _ingest_core(
                st, p1, c1, v1, s1, spec, caps_arr, k, tau,
                variant, eps, c_const, block_size, step_impl,
            )

        return jax.vmap(one)(sts, p, c, v, s)

    mapped = _shard_map(
        local,
        mesh=mesh,
        in_specs=(psh, psh, psh, psh, psh, P()),
        out_specs=psh,
    )
    return jax.jit(mapped, donate_argnums=(0,) if donate else ())


def ingest_batch_sharded_mapped(
    sts: StreamState,  # stacked: every leaf has leading shard axis S
    points: jnp.ndarray,  # (S, m, d)
    cats: jnp.ndarray,  # (S, m, gamma)
    valid: jnp.ndarray,  # (S, m)
    src: jnp.ndarray,  # (S, m) global stream indices
    spec: MatroidSpec,
    caps: Optional[jnp.ndarray],
    k: int,
    tau: int,
    *,
    variant: str = "radius",
    eps: float = 0.5,
    c_const: int = 32,
    block_size: int = 128,
    step_impl: str = "branchless",
    donate: bool = False,
) -> StreamState:
    """``shard_map`` drive of sharded ingestion: the S shard states are
    partitioned across a 1-D mesh of min(devices, S) devices (largest count
    dividing S) and each device runs its local shard group as an ordinary
    program — real branches, no select-both-branches tax, true multi-device
    parallelism. Per-shard results are bit-identical to
    ``ingest_batch_sharded`` (it is the same ``_ingest_core`` under a
    different drive); on a single device this degenerates to the vmap path
    plus shard_map dispatch overhead. ``donate=True`` consumes ``sts``
    (serving hot path: the caller reassigns its state every call)."""
    S = points.shape[0]
    caps_arr = caps if caps is not None else jnp.zeros((1,), jnp.int32)
    nd = mesh_device_count(S)
    fn = _sharded_mapped_fn(
        nd, spec, k, tau, variant, float(eps), int(c_const),
        int(block_size), step_impl, bool(donate),
    )
    return fn(sts, points, cats, valid.astype(bool), src, caps_arr)


def stream_coreset(
    points: jnp.ndarray,  # (n, d) metric-normalized stream order
    cats: jnp.ndarray,  # (n, gamma)
    valid: jnp.ndarray,  # (n,)
    spec: MatroidSpec,
    caps: Optional[jnp.ndarray],
    k: int,
    tau: int,
    *,
    slot_cap: Optional[int] = None,
    variant: str = "radius",  # "radius" (§5.2 tau-controlled) | "diameter" (Alg. 2)
    eps: float = 0.5,
    c_const: int = 32,
    block_size: int = 1,
    step_impl: str = "branchless",
) -> tuple[Coreset, StreamState]:
    """One-pass streaming coreset: init + single ingest_batch + snapshot.

    Defaults to the per-point scan: a one-shot offline pass pays the blocked
    graph's larger compile without amortizing it over repeated calls (the
    serving layer, which does amortize, opts into ``block_size=128``).
    """
    n, d = points.shape
    gamma = cats.shape[1]
    st0 = init_stream_state(d, gamma, spec, k, tau, slot_cap=slot_cap)
    st = ingest_batch(
        st0, points, cats, valid, spec, caps, k, tau,
        variant=variant, eps=eps, c_const=c_const, block_size=block_size,
        step_impl=step_impl,
    )
    return snapshot_coreset(st), st


def stream_coreset_host(
    points: np.ndarray,
    cats: Optional[np.ndarray],
    matroid,
    k: int,
    tau: int,
) -> np.ndarray:
    """Host-loop streaming for general matroids (oracle-based HANDLE).

    HANDLE 'other' case of Alg. 2: always add; if D_z gains an independent
    subset of size k, shrink to it. Returns selected indices.
    """
    n, d = points.shape
    R = None
    centers: list[int] = []
    delegates: dict[int, list[int]] = {}

    def dist(i, j):
        return float(np.linalg.norm(points[i] - points[j]))

    for i in range(n):
        if len(centers) < 2:
            centers.append(i)
            delegates[i] = [i]
            if len(centers) == 2:
                R = dist(centers[0], centers[1]) / 2.0 or 1e-30
            continue
        dmin, z = min((dist(i, c), c) for c in centers)
        if dmin > 2.0 * R:
            centers.append(i)
            delegates[i] = [i]
        else:
            dz = delegates[z]
            sub = matroid.greedy_independent(dz, k)
            if len(sub) < k:
                dz.append(i)
                sub2 = matroid.greedy_independent(dz, k)
                if len(sub2) == k:
                    delegates[z] = sub2
        while len(centers) > tau:
            R *= 2.0
            kept: list[int] = []
            for c in centers:
                if all(dist(c, c2) > R for c2 in kept):
                    kept.append(c)
            dropped = [c for c in centers if c not in kept]
            centers = kept
            for c in dropped:
                for x in delegates.pop(c):
                    dmin, z = min((dist(x, c2), c2) for c2 in centers)
                    dz = delegates[z]
                    sub = matroid.greedy_independent(dz, k)
                    if len(sub) < k:
                        dz.append(x)
                        sub2 = matroid.greedy_independent(dz, k)
                        if len(sub2) == k:
                            delegates[z] = sub2
    out = sorted({x for dz in delegates.values() for x in dz})
    return np.asarray(out, np.int64)
