"""Exhaustive DMMC solver (paper §4.4): exact best independent k-subset.

For the star/tree/cycle/bipartition variants no polynomial constant-factor
approximation is known, so the paper runs exhaustive search *on the coreset*
(|T| independent of n) — we do the same. DFS over independent sets with
matroid pruning (hereditary property: any extension of a dependent set is
dependent, so subtrees are cut early).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from ..diversity import Variant, diversity
from ..matroid import Matroid


def exhaustive_best(
    D: np.ndarray,
    matroid: Matroid,
    k: int,
    idxs: Sequence[int],
    variant: Variant,
    *,
    max_nodes: int = 2_000_000,
) -> tuple[list[int], float, bool]:
    """Returns (best subset, best diversity, completed flag).

    completed=False means the node budget was hit (result is best-so-far).
    """
    idxs = [int(i) for i in idxs]
    m = len(idxs)
    best_set: list[int] = []
    best_val = -1.0
    nodes = 0
    complete = True

    cur: list[int] = []

    def rec(start: int) -> None:
        nonlocal best_set, best_val, nodes, complete
        if nodes >= max_nodes:
            complete = False
            return
        nodes += 1
        if len(cur) == k:
            val = diversity(D[np.ix_(cur, cur)], variant)
            if val > best_val:
                best_val = val
                best_set = list(cur)
            return
        # not enough points left to reach k
        if m - start < k - len(cur):
            return
        for pos in range(start, m):
            v = idxs[pos]
            if matroid.can_extend(cur, v):
                cur.append(v)
                rec(pos + 1)
                cur.pop()
                if nodes >= max_nodes:
                    return

    rec(0)
    return best_set, max(best_val, 0.0), complete
