"""AMT local search for sum-DMMC (Abbassi-Mirrokni-Thakur, KDD'13).

The paper's final-stage solver for the sum variant: start from an arbitrary
(here: greedy) independent set of size k, repeatedly swap a solution point u
for an outside point v whenever X - u + v is independent and improves the sum
diversity by a factor >= (1 + gamma); gamma=0 keeps swapping while there is
any strict improvement (what the paper uses on coresets, footnote 5).

Runs on host over a precomputed distance matrix — the whole point of the
paper is that this expensive step touches only the coreset, never S.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from ..matroid import Matroid


def greedy_init(
    D: np.ndarray, matroid: Matroid, k: int, idxs: Sequence[int]
) -> list[int]:
    """Greedy independent set maximizing marginal sum-of-distances."""
    chosen: list[int] = []
    cand = list(idxs)
    # seed with the point of max eccentricity to its farthest feasible mate
    while len(chosen) < k:
        best, best_gain = None, -1.0
        for v in cand:
            if v in chosen or not matroid.can_extend(chosen, v):
                continue
            gain = float(D[v, chosen].sum()) if chosen else float(D[v].sum())
            if gain > best_gain:
                best, best_gain = v, gain
        if best is None:
            break
        chosen.append(best)
    return chosen


def local_search_sum(
    D: np.ndarray,
    matroid: Matroid,
    k: int,
    idxs: Sequence[int],
    *,
    gamma: float = 0.0,
    max_sweeps: int = 64,
    init: Optional[Sequence[int]] = None,
) -> tuple[list[int], float, int]:
    """Returns (solution indices, sum diversity, #swaps performed).

    D is the full distance matrix over the ground set; idxs restricts the
    search to a subset (e.g. the coreset's members).
    """
    idxs = [int(i) for i in idxs]
    X = list(init) if init is not None else greedy_init(D, matroid, k, idxs)
    if len(X) < k:
        return X, float(D[np.ix_(X, X)].sum() / 2.0), 0

    inside = set(X)
    div = float(D[np.ix_(X, X)].sum() / 2.0)
    swaps = 0
    for _ in range(max_sweeps):
        improved = False
        # row sums of D restricted to X, for O(1) swap deltas
        row = {u: float(D[u, X].sum()) for u in X}
        for v in idxs:
            if v in inside:
                continue
            dv = float(D[v, X].sum())
            for u in list(X):
                # div(X - u + v) = div - row[u] + dv - d(u, v)
                new_div = div - row[u] + dv - float(D[u, v])
                if new_div <= div * (1.0 + gamma) or new_div <= div:
                    continue
                Xm = [w for w in X if w != u] + [v]
                if not matroid.is_independent(Xm):
                    continue
                X = Xm
                inside.discard(u)
                inside.add(v)
                div = new_div
                swaps += 1
                row = {w: float(D[w, X].sum()) for w in X}
                improved = True
                break
        if not improved:
            break
    return X, div, swaps
