"""Vectorized greedy batch engine for the star/tree variants.

For star/tree/cycle/bipartition the exact reference is exhaustive search
(``host_exhaustive``) — no polynomial exact algorithm is known, which is
why the paper runs it on the coreset only. That is still the serving
bottleneck for large query bursts, so this engine offers a *fast
approximate* alternative: a vmapped objective-greedy — at each step add
the feasible candidate maximizing the resulting set's objective, evaluated
with the jit objectives of ``core.diversity`` (``star_div``/``tree_div``)
on a masked submatrix.

Because greedy is a heuristic, this engine declares ``exact_parity =
False``: ``engine="auto"`` never picks it. Queries opt in explicitly with
``engine="jit_greedy"`` or ``DiversityQuery(engine_hint="jit_greedy")``,
keeping the host exact answer one flag away (the parity/fallback engine).

Feasibility reuses the same machinery as the sum engine: counts<caps for
uniform/partition, exact masked augmenting paths for transversal.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..diversity import Variant, star_div, tree_div
from .base import (
    EngineSolution,
    SolveContext,
    SolveSpec,
    SolverEngine,
    selection_value,
)
from .jit_sum import (
    bucket_pow2,
    jit_cell_eligible,
    pad_query_arrays,
    partition_arrays,
)
from .matching import augment, cats_onehot, feasible_all

_INF = jnp.float32(jnp.inf)


def _masked_star(Dsub: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """star_div over the valid slots only: invalid rows are pushed to +inf
    (never the min), invalid columns contribute 0 to valid rows' sums."""
    vv = valid[:, None] & valid[None, :]
    D1 = jnp.where(vv, Dsub, 0.0) + jnp.where(valid, 0.0, _INF)[:, None]
    return star_div(D1)


def _masked_tree(Dsub: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """tree_div over the valid slots only: invalid slots attach to slot 0
    by a zero-weight edge (adding 0 to the MST) and are unreachable
    otherwise, so Prim's fixed-length scan still spans every slot."""
    vv = valid[:, None] & valid[None, :]
    D1 = jnp.where(vv, Dsub, _INF)
    col0 = jnp.where(valid, Dsub[:, 0], 0.0)
    D1 = D1.at[:, 0].set(col0).at[0, :].set(col0)
    return tree_div(D1)


_MASKED = {"star": _masked_star, "tree": _masked_tree}


def _candidate_values(D, sel, nsel, variant, kmax):
    """Objective of (current selection + candidate v) for every v: the
    candidate sits in slot ``nsel`` of the padded submatrix."""
    idx = jnp.maximum(sel, 0)
    slots = jnp.arange(kmax, dtype=jnp.int32)
    masked = _MASKED[variant]

    def eval_v(v):
        idx2 = idx.at[nsel].set(v)
        Ds = D[idx2][:, idx2]
        return masked(Ds, slots <= nsel)

    return jax.vmap(eval_v)(jnp.arange(D.shape[0]))


def _greedy_one(D, can_fn, add_fn, feas0, allow, k, variant, kmax):
    """Shared greedy loop; ``can_fn``/``add_fn`` inject the matroid
    feasibility (counts-based or matching-based)."""
    rowsum_all = jnp.sum(D, axis=1)  # step-0 tie-break: most eccentric

    def body(i, carry):
        sel, selmask, feas, nsel = carry
        can = allow & ~selmask & can_fn(feas)
        vals = _candidate_values(D, sel, nsel, variant, kmax)
        gains = jnp.where(nsel == 0, rowsum_all, vals)
        v = jnp.argmax(jnp.where(can, gains, -_INF))
        take = (i < k) & jnp.any(can)

        def add(c):
            sel, selmask, feas, nsel = c
            return (
                sel.at[nsel].set(v),
                selmask.at[v].set(True),
                add_fn(feas, v),
                nsel + 1,
            )

        return jax.lax.cond(take, add, lambda c: c, carry)

    init = (
        jnp.full((kmax,), -1, jnp.int32),
        jnp.zeros((D.shape[0],), bool),
        feas0,
        jnp.int32(0),
    )
    sel, _selmask, _feas, nsel = jax.lax.fori_loop(0, kmax, body, init)
    return sel, nsel


@functools.partial(jax.jit, static_argnames=("variant", "kmax"))
def solve_greedy_batch(
    D: jnp.ndarray,  # (m, m)
    cats: jnp.ndarray,  # (m,) int32 single-label (zeros: uniform)
    caps: jnp.ndarray,  # (B, h)
    allow: jnp.ndarray,  # (B, m)
    ks: jnp.ndarray,  # (B,)
    *,
    variant: str,
    kmax: int,
):
    """Batched star/tree greedy under uniform/partition matroids.
    Returns (sel (B, kmax) -1-padded, nsel (B,))."""
    h = caps.shape[1]

    def one(caps_q, allow_q, k):
        can_fn = lambda counts: counts[cats] < caps_q[cats]
        add_fn = lambda counts, v: counts.at[cats[v]].add(1)
        feas0 = jnp.zeros((h,), jnp.int32)
        return _greedy_one(D, can_fn, add_fn, feas0, allow_q, k, variant, kmax)

    return jax.vmap(one, in_axes=(0, 0, 0))(caps, allow, ks)


@functools.partial(jax.jit, static_argnames=("variant", "kmax"))
def solve_greedy_batch_transversal(
    D: jnp.ndarray,  # (m, m)
    oh: jnp.ndarray,  # (m, h) bool
    allow: jnp.ndarray,  # (B, m)
    ks: jnp.ndarray,  # (B,)
    *,
    variant: str,
    kmax: int,
):
    """Batched star/tree greedy under ONE transversal matroid."""
    h = oh.shape[1]

    def one(allow_q, k):
        can_fn = lambda ms_pt: feasible_all(oh, ms_pt, kmax)
        add_fn = lambda ms_pt, v: augment(oh, ms_pt, v, kmax)
        feas0 = jnp.full((h,), -1, jnp.int32)
        return _greedy_one(D, can_fn, add_fn, feas0, allow_q, k, variant, kmax)

    return jax.vmap(one, in_axes=(0, 0))(allow, ks)


class JitGreedyBatchEngine(SolverEngine):
    """Registry face of the batched greedy star/tree solvers."""

    name = "jit_greedy"
    priority = 20
    exact_parity = False  # greedy heuristic; host exhaustive is exact

    def supports(self, variant: Variant, matroid_kind: str) -> bool:
        return variant in ("star", "tree") and matroid_kind in (
            "uniform", "partition", "transversal"
        )

    def eligible(self, ctx: SolveContext, spec: SolveSpec) -> bool:
        return jit_cell_eligible(self, ctx, spec)

    def solve_batch(
        self, ctx: SolveContext, specs: Sequence[SolveSpec]
    ) -> list[EngineSolution]:
        # one jit dispatch per variant present in the group
        by_variant: dict[str, list[int]] = {}
        for i, s in enumerate(specs):
            by_variant.setdefault(s.variant, []).append(i)
        out: list[EngineSolution] = [None] * len(specs)  # type: ignore
        for variant, idxs in by_variant.items():
            group = [specs[i] for i in idxs]
            Bb = bucket_pow2(len(group))
            kmax = bucket_pow2(max(s.k for s in group))
            allow_b, ks, _gammas = pad_query_arrays(ctx, group, Bb)
            if ctx.spec.kind == "transversal":
                oh = cats_onehot(ctx.cats, ctx.spec.num_categories)
                sel, nsel = solve_greedy_batch_transversal(
                    jnp.asarray(ctx.D), jnp.asarray(oh),
                    jnp.asarray(allow_b), jnp.asarray(ks),
                    variant=variant, kmax=kmax,
                )
            else:
                cats1, caps_b = partition_arrays(ctx, group, Bb)
                sel, nsel = solve_greedy_batch(
                    jnp.asarray(ctx.D), jnp.asarray(cats1),
                    jnp.asarray(caps_b), jnp.asarray(allow_b),
                    jnp.asarray(ks), variant=variant, kmax=kmax,
                )
            sel, nsel = np.asarray(sel), np.asarray(nsel)
            for j, i in enumerate(idxs):
                loc = sel[j, : nsel[j]].astype(np.int64)
                out[i] = EngineSolution(
                    local_indices=loc,
                    value=selection_value(ctx.D, loc, variant),
                    engine=self.name,
                )
        return out
