"""Jit-side bipartite matching for transversal matroids.

Two layers of machinery, both static-shape and mask-based so they can run
inside jit/vmap:

* ``greedy_matching_slots`` — the greedy matching witness used by the
  streaming shrink step (Alg. 2): sound for proving "an independent size-k
  subset exists", may overcount nothing but can under-match. Lifted here
  from ``core.streaming._shrink`` so the scan and the solvers share one
  implementation.

* Exact augmenting-path primitives (Kuhn's algorithm over masks) used by
  the batched final-stage solvers: a transversal feasibility check is
  "does an augmenting path from candidate v exist given a complete
  matching of the current selection" — exactly the host oracle's
  ``can_extend`` truth value, independent of *which* complete matching is
  maintained (standard alternating-path argument), so the jit solver makes
  bit-identical accept/reject decisions to the host local search.

Matching representation for the exact primitives: ``ms_pt: int32[h]`` maps
category -> matched point id (local row of the coreset matrix), -1 if the
category is free. Category incidence is a dense one-hot ``oh: bool[m, h]``
(points on the left, categories on the right).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def cats_onehot(cats: np.ndarray, num_categories: int) -> np.ndarray:
    """(m, gamma) -1-padded label matrix -> bool[m, h] incidence."""
    cats = np.asarray(cats, np.int64)
    if cats.ndim == 1:
        cats = cats[:, None]
    m = cats.shape[0]
    oh = np.zeros((m, num_categories), bool)
    rows, cols = np.nonzero(cats >= 0)
    oh[rows, cats[rows, cols]] = True
    return oh


# --------------------------------------------------------------------------
# Greedy matching witness (shared with core.streaming._shrink)
# --------------------------------------------------------------------------


def greedy_matching_slots(
    cats: jnp.ndarray,  # (SLOT, gamma) int32, -1 padded
    valid: jnp.ndarray,  # (SLOT,) bool
    num_categories: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """First-free-category greedy matching over slot order.

    Returns (used: bool[h] categories consumed, matched: bool[SLOT] slots
    that found a category). Exactly the loop the streaming shrink step has
    always run — kept bit-identical (tests/test_blocked_ingest.py pins the
    scan output across refactors).
    """
    slot_n, _gamma = cats.shape

    def body(s, carry):
        used, matched = carry

        def try_slot(carry):
            used, matched = carry
            free = (cats[s] >= 0) & ~used[jnp.maximum(cats[s], 0)]
            j = jnp.argmax(free)  # first free category slot
            ok = jnp.any(free)
            cat = jnp.maximum(cats[s, j], 0)
            used = jax.lax.cond(
                ok, lambda u: u.at[cat].set(True), lambda u: u, used
            )
            matched = matched.at[s].set(ok)
            return used, matched

        return jax.lax.cond(valid[s], try_slot, lambda c: c, carry)

    used0 = jnp.zeros((num_categories,), bool)
    matched0 = jnp.zeros((slot_n,), bool)
    return jax.lax.fori_loop(0, slot_n, body, (used0, matched0))


# --------------------------------------------------------------------------
# Exact augmenting-path primitives (Kuhn over masks)
# --------------------------------------------------------------------------


def reach_matrix(oh: jnp.ndarray, ms_pt: jnp.ndarray) -> jnp.ndarray:
    """bool[h, h] one-step alternating reachability between categories.

    M[c, c'] is True iff category c is matched (to point p = ms_pt[c]) and
    p also holds category c' — i.e. an alternating path entering c can
    continue to c' through p.
    """
    p = jnp.maximum(ms_pt, 0)
    return oh[p] & (ms_pt >= 0)[:, None]


def feasible_all(
    oh: jnp.ndarray,  # (m, h) bool point-category incidence
    ms_pt: jnp.ndarray,  # (h,) int32 matching (point id or -1)
    iters: int,  # >= current matching size (kmax is always safe)
) -> jnp.ndarray:
    """bool[m]: for every point v, does an augmenting path from v exist?

    Equivalently: is (current selection) + {v} independent in the
    transversal matroid — the host ``can_extend`` answer for all m
    candidates at once. Fixpoint reachability over the h-category graph;
    an alternating path traverses at most one matched point per step, so
    ``iters`` >= matching size reaches the fixpoint.
    """
    M = reach_matrix(oh, ms_pt).astype(jnp.float32)
    free = (ms_pt < 0)[None, :]

    def step(_, reach):
        return reach | ((reach.astype(jnp.float32) @ M) > 0)

    reach = jax.lax.fori_loop(0, iters, step, oh)
    return jnp.any(reach & free, axis=1)


def swap_feasible(
    oh: jnp.ndarray,  # (m, h) bool
    ms_pt: jnp.ndarray,  # (h,) int32
    sel: jnp.ndarray,  # (kmax,) int32 selected point ids (-1 padded)
    v,  # candidate point id
) -> jnp.ndarray:
    """bool[kmax]: for every selected slot j, is X - sel[j] + v independent?

    Variant j frees sel[j]'s matched category, then asks for an augmenting
    path from v. Rows for invalid slots (sel[j] < 0) are garbage; callers
    mask them with ``slots < nsel``.
    """
    kmax = sel.shape[0]
    h = ms_pt.shape[0]
    u = jnp.maximum(sel, 0)
    ms_var = jnp.where(ms_pt[None, :] == u[:, None], -1, ms_pt[None, :])
    Ms = jax.vmap(reach_matrix, in_axes=(None, 0))(oh, ms_var)
    Ms = Ms.astype(jnp.float32)  # (kmax, h, h)
    free = ms_var < 0  # (kmax, h)
    reach0 = jnp.broadcast_to(oh[v], (kmax, h))

    def step(_, reach):
        nxt = jnp.einsum("jc,jcd->jd", reach.astype(jnp.float32), Ms) > 0
        return reach | nxt

    reach = jax.lax.fori_loop(0, kmax, step, reach0)
    return jnp.any(reach & free, axis=1)


def augment(
    oh: jnp.ndarray,  # (m, h) bool
    ms_pt: jnp.ndarray,  # (h,) int32
    v,  # point id to insert
    iters: int,  # >= matching size (kmax is always safe)
) -> jnp.ndarray:
    """Insert point v into the matching via one augmenting path (BFS +
    flip). Returns the updated ``ms_pt``; a no-op when no path exists (the
    callers always pre-check feasibility, this just keeps the masked
    branch safe)."""
    h = ms_pt.shape[0]
    ohv = oh[v]
    M = reach_matrix(oh, ms_pt)
    # from_cat[c]: BFS parent category of c (-1: reached directly from v,
    # -2: unvisited)
    from_cat0 = jnp.where(ohv, jnp.int32(-1), jnp.int32(-2))

    def bfs(_, carry):
        from_cat, frontier = carry
        cand = frontier[:, None] & M  # (h, h): edge c -> c'
        new = jnp.any(cand, axis=0) & (from_cat == -2)
        parent = jnp.argmax(cand, axis=0).astype(jnp.int32)
        return jnp.where(new, parent, from_cat), new

    from_cat, _ = jax.lax.fori_loop(0, iters, bfs, (from_cat0, ohv))
    endpoint = (from_cat > -2) & (ms_pt < 0)  # visited AND free
    ok = jnp.any(endpoint)
    c_end = jnp.argmax(endpoint).astype(jnp.int32)

    # Walk the path back from the free endpoint, shifting each matched
    # point one category forward; the category adjacent to v gets v.
    def cond_fn(carry):
        _ms, _c, done, i = carry
        return ~done & (i <= h)

    def body_fn(carry):
        ms, c, _done, i = carry
        cp = from_cat[c]
        moved = jnp.where(cp < 0, jnp.int32(v), ms[jnp.maximum(cp, 0)])
        return ms.at[c].set(moved), jnp.maximum(cp, 0), cp < 0, i + 1

    ms2, _, _, _ = jax.lax.while_loop(
        cond_fn, body_fn, (ms_pt, c_end, ~ok, jnp.int32(0))
    )
    return ms2
