"""Solver-engine protocol + registry for the final-stage DMMC solve.

The paper's split (§4.4) makes the final solver a small, swappable
component: it only ever sees the coreset distance matrix. This module is
the seam — every final-stage solver (host local search, host exhaustive
search, the jit batched engines) is a registered ``SolverEngine`` and both
the offline driver (``solve_dmmc`` -> ``final_solve``) and the online
service (``DiversityService.query/query_batch``) dispatch through the
registry instead of hand-rolled if-chains.

An engine declares

* ``supports(variant, matroid_kind)`` — its static cell coverage of the
  (diversity variant x matroid kind) grid;
* ``eligible(ctx, spec)`` — data-dependent refinement (e.g. the jit
  partition path needs single-label categories);
* ``exact_parity`` — whether its selections provably match the host
  reference engine on every supported cell. Only parity engines are
  candidates for ``engine="auto"``; non-parity engines (the greedy
  star/tree batch engine) must be requested explicitly via ``engine=`` or
  a query's ``engine_hint``.
* ``solve_one`` / ``solve_batch`` — the solve itself. Batched engines
  amortize one jit dispatch over the whole group; host engines loop.

All engines report the objective through one canonical evaluator
(``selection_value``: float64, selection sorted before evaluation), so two
engines that pick the same set report the *same float* — that is what
lets the cross-engine parity tests assert exact value equality.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from ... import obs
from ..diversity import VARIANTS, Variant, diversity
from ..matroid import Matroid, MatroidSpec

MATROID_KINDS: tuple[str, ...] = (
    "uniform", "partition", "transversal", "general"
)


@dataclasses.dataclass(frozen=True)
class SolveSpec:
    """One final-stage solve request, resolved against a coreset context.

    ``caps`` is a per-request partition-caps override (None = the
    context's default caps); ``allow`` is the resolved bool[m] candidate
    mask (None = all m rows are candidates). ``idxs`` optionally pins an
    explicit candidate *order* (with duplicates preserved) — the host
    solvers' tie-breaks are visit-order dependent, so ``final_solve``
    threads its caller's sequence through unchanged; the jit engines scan
    ascending and refuse order-sensitive requests (``eligible`` returns
    False for a non-ascending ``idxs``). Without ``idxs``, candidates are
    visited in ascending row order.
    """

    k: int
    variant: Variant = "sum"
    gamma: float = 0.0
    caps: Optional[tuple[int, ...]] = None
    allow: Optional[np.ndarray] = None
    idxs: Optional[tuple[int, ...]] = None

    def allow_mask(self, m: int) -> np.ndarray:
        if self.idxs is not None:
            mask = np.zeros((m,), bool)
            mask[np.asarray(self.idxs, np.int64)] = True
            return mask
        if self.allow is None:
            return np.ones((m,), bool)
        return np.asarray(self.allow, bool)

    def candidate_idxs(self, m: int) -> list[int]:
        """Candidates in visit order (host solvers' scan order)."""
        if self.idxs is not None:
            return [int(i) for i in self.idxs]
        return np.flatnonzero(self.allow_mask(m)).tolist()

    def ascending_candidates(self, m: int) -> bool:
        """True unless ``idxs`` pins a custom (non-ascending) order."""
        if self.idxs is None:
            return True
        arr = np.asarray(self.idxs, np.int64)
        return bool(np.all(arr[1:] > arr[:-1]))


@dataclasses.dataclass
class SolveContext:
    """Everything engines may need about the coreset being solved on.

    ``matroid_fn`` builds the host oracle for a request (applying
    per-request caps); jit engines instead read ``cats``/``caps``
    directly. ``cats`` may be None when the caller only has a host oracle
    (then only host engines are eligible).
    """

    D: np.ndarray  # (m, m) distances
    spec: MatroidSpec
    cats: Optional[np.ndarray] = None  # (m, gamma) int32, -1 padded
    caps: Optional[np.ndarray] = None  # default partition caps
    matroid_fn: Optional[Callable[[SolveSpec], Matroid]] = None

    def __post_init__(self):
        if self.cats is not None:
            cats = np.asarray(self.cats, np.int32)
            if cats.ndim == 1:  # single-label shorthand -> (m, 1)
                cats = cats[:, None]
            self.cats = cats

    @property
    def size(self) -> int:
        return int(self.D.shape[0])

    def partition_multilabel(self) -> bool:
        """True iff some row carries a second real (non-padding) label —
        the case the partition matroid cannot represent."""
        return (
            self.cats is not None
            and self.cats.ndim == 2
            and self.cats.shape[1] > 1
            and bool(np.any(self.cats[:, 1:] >= 0))
        )


@dataclasses.dataclass
class EngineSolution:
    local_indices: np.ndarray  # rows of ctx.D, solver order
    value: float  # canonical objective (selection_value)
    engine: str  # name of the engine that produced it


def selection_value(D: np.ndarray, sel: Sequence[int], variant: Variant) -> float:
    """Canonical objective of a selection: float64, rows sorted first.

    Sorting makes the float result a function of the selected *set* only,
    so engines that agree on the set report bitwise-equal values
    regardless of the order their search visited it in.
    """
    loc = np.sort(np.asarray(list(sel), np.int64))
    if loc.size <= 1:
        return 0.0
    sub = np.asarray(D, np.float64)[np.ix_(loc, loc)]
    return float(diversity(sub, variant))


class SolverEngine:
    """Base class: subclass, set the class attributes, register."""

    name: str = "?"
    priority: int = 100  # lower = preferred among eligible parity engines
    exact_parity: bool = False  # selections match the host reference

    def supports(self, variant: Variant, matroid_kind: str) -> bool:
        raise NotImplementedError

    def eligible(self, ctx: SolveContext, spec: SolveSpec) -> bool:
        return self.supports(spec.variant, ctx.spec.kind)

    def solve_one(self, ctx: SolveContext, spec: SolveSpec) -> EngineSolution:
        return self.solve_batch(ctx, [spec])[0]

    def solve_batch(
        self, ctx: SolveContext, specs: Sequence[SolveSpec]
    ) -> list[EngineSolution]:
        return [self.solve_one(ctx, s) for s in specs]

    # -- cross-tenant stacking (see stacked.py) ------------------------
    # A stack-capable engine answers several single-tenant spec groups
    # ("lanes" of (ctx, specs), differing only in their pdist leaf and
    # matroid view) in ONE device dispatch. Default: not capable.

    def stack_eligible(self, ctx: SolveContext, spec: SolveSpec) -> bool:
        return False

    def solve_batch_stacked(
        self, lanes: Sequence[tuple[SolveContext, Sequence[SolveSpec]]]
    ) -> list[list[EngineSolution]]:
        raise NotImplementedError(
            f"engine {self.name!r} has no stacked solve path"
        )

    def __repr__(self):
        return f"<SolverEngine {self.name!r}>"


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, SolverEngine] = {}

# back-compat spellings from the pre-registry service API
_ALIASES = {"vmap": "jit_sum"}


def register_engine(engine: SolverEngine, *, overwrite: bool = False) -> SolverEngine:
    """Register an engine instance under ``engine.name``. Third parties
    use this to plug in custom engines (see README "Solver engines")."""
    if engine.name in _REGISTRY and not overwrite:
        raise ValueError(f"engine {engine.name!r} already registered")
    _REGISTRY[engine.name] = engine
    return engine


def registered_engines() -> list[SolverEngine]:
    """All engines, best (lowest priority value) first."""
    return sorted(_REGISTRY.values(), key=lambda e: (e.priority, e.name))


def get_engine(name: str) -> SolverEngine:
    name = _ALIASES.get(name, name)
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown solver engine {name!r}; registered: "
            f"{sorted(_REGISTRY)} (+ aliases {sorted(_ALIASES)}, 'host', 'auto')"
        )
    return _REGISTRY[name]


def resolve_engine(
    name: str, ctx: SolveContext, spec: SolveSpec
) -> SolverEngine:
    """Resolve an explicit engine request (not "auto") for one request.

    ``"host"`` resolves to whichever host reference engine covers the
    variant (local search for sum, exhaustive otherwise). An explicitly
    named engine that is not eligible for the request raises.
    """
    if name == "host":
        for e in registered_engines():
            if e.name.startswith("host") and e.eligible(ctx, spec):
                return e
        raise ValueError(
            f"no host engine for variant={spec.variant!r} under "
            f"{ctx.spec.kind!r}"
        )
    e = get_engine(name)
    if not e.eligible(ctx, spec):
        raise ValueError(
            f"engine {e.name!r} does not support variant={spec.variant!r} "
            f"under matroid kind {ctx.spec.kind!r} for this coreset"
        )
    return e


def _auto_candidates(
    ctx: SolveContext, spec: SolveSpec, *, hint: Optional[str] = None
) -> tuple[SolverEngine, ...]:
    """Candidate engines for one ``engine="auto"`` request, best-first.

    A query ``hint`` names a specific engine (e.g. the non-parity
    ``jit_greedy``) and pins the candidate set to it; a hint naming a
    *registered* engine that is not eligible for this request falls back
    to the auto policy rather than failing the query, but an unknown
    engine name raises — silently downgrading a typo'd hint to a slower
    engine would hide the caller's bug. Without an applicable hint the
    candidates are every eligible engine with the host-parity guarantee
    (priority order) — any of them returns the same answer, which is what
    makes cost-based picking among them a pure latency decision.
    """
    if hint == "host":
        return (resolve_engine("host", ctx, spec),)
    if hint is not None:
        e = get_engine(hint)  # unknown name -> ValueError
        if e.eligible(ctx, spec):
            return (e,)
        # soft hint: eligible nowhere here, fall through to the auto policy
    cands = tuple(
        e for e in registered_engines()
        if e.exact_parity and e.eligible(ctx, spec)
    )
    if not cands:
        raise ValueError(
            f"no registered engine covers variant={spec.variant!r} under "
            f"matroid kind {ctx.spec.kind!r}"
        )
    return cands


def select_engine(
    ctx: SolveContext,
    spec: SolveSpec,
    *,
    hint: Optional[str] = None,
    cost_model=None,
    batch_size: int = 1,
) -> SolverEngine:
    """The ``engine="auto"`` policy for a single request.

    Without a ``cost_model`` this is the historical static policy: the
    highest-priority eligible engine with the host-parity guarantee — so
    an auto answer always equals the host answer on the same coreset.
    With a ``cost_model`` (``core.solvers.cost_model.CostModel``), the
    parity constraint still bounds the candidate set, but the pick within
    it is argmin of ``estimate(engine, batch_size, kmax, m)`` — host
    engines win tiny batches where dispatch dominates, jit engines win at
    scale, and the crossover is measured rather than asserted.
    """
    cands = _auto_candidates(ctx, spec, hint=hint)
    if cost_model is None or len(cands) == 1:
        return cands[0]
    winner, ests = cost_model.choose(
        [e.name for e in cands], B=batch_size, kmax=spec.k, m=ctx.size
    )
    cost_model.record_decision(
        engine=winner, candidates=ests,
        B=batch_size, kmax=spec.k, m=ctx.size,
    )
    return get_engine(winner)


def partition_by_engine(
    ctx: SolveContext,
    specs: Sequence[SolveSpec],
    *,
    engine: str = "auto",
    hints: Optional[Sequence[Optional[str]]] = None,
    cost_model=None,
    batch_size: Optional[int] = None,
    stacked: bool = False,
) -> dict[str, list[int]]:
    """Split a batch into per-engine groups (engine name -> spec indices).

    ``engine="auto"`` applies the auto policy per request (honoring
    per-request hints); any other name forces every request through that
    engine (raising if one is ineligible).

    With a ``cost_model``, auto requests are first grouped by their
    *candidate set* (hint-pinned requests bypass this), and each group is
    routed as a unit: the model sees the group's true batch size ``B``
    and its max ``k``, so ten concurrent B=1 callers coalesced into one
    group route like one B=10 batch — per-request argmin would always see
    B=1 and never cross over to the amortizing jit engines.
    ``batch_size`` overrides the B the model sees (the micro-batch
    coalescer partitions per caller for admission but routes with the
    merged group's size); ``stacked=True`` marks the decision as priced
    for a cross-tenant stacked launch in the audit ring. Decisions are
    recorded in the model's audit ring and counted under
    ``solve.dispatch.cost_routed``. ``cost_model=None`` (the default, and
    what the offline ``solve_dmmc``/``final_solve`` drivers use) keeps
    the static priority policy bit-for-bit.
    """
    groups: dict[str, list[int]] = {}
    undecided: dict[tuple[str, ...], list[int]] = {}
    for i, s in enumerate(specs):
        if engine == "auto":
            h = hints[i] if hints is not None else None
            cands = _auto_candidates(ctx, s, hint=h)
            if cost_model is None or len(cands) == 1:
                groups.setdefault(cands[0].name, []).append(i)
            else:
                key = tuple(e.name for e in cands)
                undecided.setdefault(key, []).append(i)
        else:
            e = resolve_engine(engine, ctx, s)
            groups.setdefault(e.name, []).append(i)
    reg = obs.default_registry()
    for names, idxs in undecided.items():
        kmax = max(specs[i].k for i in idxs)
        B = len(idxs) if batch_size is None else max(batch_size, len(idxs))
        winner, ests = cost_model.choose(names, B=B, kmax=kmax, m=ctx.size)
        cost_model.record_decision(
            engine=winner, candidates=ests, B=B, kmax=kmax, m=ctx.size,
            stacked=stacked,
        )
        reg.counter("solve.dispatch.cost_routed", engine=winner).inc(
            len(idxs)
        )
        groups.setdefault(winner, []).extend(idxs)
    for idxs in groups.values():
        idxs.sort()
    for name, idxs in groups.items():
        reg.counter(
            "solve.dispatch.requests", engine=name, requested=engine
        ).inc(len(idxs))
    return groups


def coverage_matrix() -> dict[tuple[str, str], list[str]]:
    """(variant, matroid_kind) -> engine names statically covering the
    cell, best-first. The README's coverage table is generated from this."""
    out: dict[tuple[str, str], list[str]] = {}
    for v in VARIANTS:
        for kind in MATROID_KINDS:
            out[(v, kind)] = [
                e.name for e in registered_engines() if e.supports(v, kind)
            ]
    return out
