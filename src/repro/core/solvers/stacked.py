"""Cross-tenant stacked solves: one device dispatch for a mixed window.

Tenants of one serving frontend answer over the *same* published epoch:
their cache entries share the coreset rows and differ only in the pdist
matrix (metric normalization) and the matroid view (cats/caps). For the
counts-family ``jit_sum`` kernel every vmapped row is already
composition-independent — a row's greedy + local-search decisions read
only its own ``(D, cats, caps, allow, k, gamma)`` leaves — so a window
holding queries for several tenants can legally execute as ONE stacked
launch with a batched pdist leaf instead of one launch per tenant. That
is §3 composability pointed at the solve dispatch: the per-call overhead
the coalescer amortizes across callers, this module amortizes across
tenants.

Bit-identity (the parity contract ``tests/test_stacked_solve.py`` pins):
the stacked kernel is a ``lax.scan`` over tenant lanes whose body is the
*unmodified* per-tenant row solver (``jit_sum._solve_sum_one``) vmapped
with an unmapped ``(m, m)`` D — each scan step slices one tenant's
matrix out of the batched leaf, so every matmul runs at the same shape
and accumulation as the per-tenant dispatch. (A gather-form
``vmap(f(Ds[t], ...))`` was measurably NOT safe: the batched matmul
accumulates in a different order and flips greedy argmax decisions on
tie-heavy data.) The remaining freedom — the pow-2 row padding differing
from what per-tenant dispatch would pick — is exactly the freedom the
shipped coalescer already exercises, and the same parity suites pin it.

Scope: ``variant="sum"`` under uniform/partition matroids (the counts
``counts < caps`` feasibility path). Transversal lanes carry a
per-tenant one-hot incidence whose width varies; host engines have no
batched kernel at all — both fall back to per-tenant dispatch in the
frontend.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ... import obs
from .base import (
    EngineSolution,
    SolveContext,
    SolveSpec,
    SolverEngine,
    selection_value,
)
from .jit_sum import _solve_sum_one, bucket_pow2, jit_cell_eligible

# one tenant lane of a stacked solve: (context, specs routed to it)
Lane = tuple[SolveContext, Sequence[SolveSpec]]


def counts_stack_eligible(
    engine: SolverEngine, ctx: SolveContext, spec: SolveSpec
) -> bool:
    """Can this request ride a stacked counts-family launch?  The jit
    cell eligibility rules apply unchanged; transversal is excluded
    because its one-hot incidence width is a per-tenant static shape."""
    if ctx.spec.kind not in ("uniform", "partition"):
        return False
    return jit_cell_eligible(engine, ctx, spec)


@functools.partial(jax.jit, static_argnames=("kmax", "max_sweeps"))
def solve_sum_batch_stacked(
    Ds: jnp.ndarray,  # (T, m, m) per-lane cached distances
    cats_s: jnp.ndarray,  # (T, m) int32 single-label categories
    caps: jnp.ndarray,  # (T, Bt, h) per-row caps
    allow: jnp.ndarray,  # (T, Bt, m) per-row candidate masks
    ks: jnp.ndarray,  # (T, Bt)
    gammas: jnp.ndarray,  # (T, Bt)
    *,
    kmax: int,
    max_sweeps: int = 64,
):
    """T tenant lanes of Bt sum-DMMC rows each, ONE dispatch.  Returns
    (sel (T, Bt, kmax) -1-padded, nsel (T, Bt), div (T, Bt)).

    ``lax.scan`` (not an outer vmap) on purpose: inside each scan step
    the lane's D is a concrete (m, m) operand, so the inner vmapped
    solver lowers to the very same unbatched-matrix HLO as the
    per-tenant ``solve_sum_batch`` — which is what makes the per-row
    results bit-identical rather than merely close.
    """
    f = functools.partial(_solve_sum_one, kmax=kmax, max_sweeps=max_sweeps)

    def lane(carry, xs):
        D, cats, caps_t, allow_t, ks_t, g_t = xs
        out = jax.vmap(f, in_axes=(None, None, 0, 0, 0, 0))(
            D, cats, caps_t, allow_t, ks_t, g_t
        )
        return carry, out

    with jax.named_scope("solver/jit_sum_stacked"):
        _, outs = jax.lax.scan(
            lane, jnp.int32(0), (Ds, cats_s, caps, allow, ks, gammas)
        )
    return outs


def solve_stacked(lanes: Sequence[Lane]) -> list[list[EngineSolution]]:
    """Execute several single-tenant spec groups as one stacked launch.

    Every lane must be counts-stack eligible (caller's responsibility —
    see ``counts_stack_eligible``) and share the coreset size and D
    dtype. Shapes bucket to powers of two independently per axis
    (lanes T, rows-per-lane Bt, kmax), so the compile cache is keyed the
    same way the per-tenant kernel's is. Returns per-lane solution
    lists in lane order.
    """
    if not lanes:
        return []
    m = lanes[0][0].size
    dtype = np.asarray(lanes[0][0].D).dtype
    for ctx, _specs in lanes:
        if ctx.size != m:
            raise ValueError(
                f"stacked lanes must share the coreset size: {ctx.size} != {m}"
            )
        if np.asarray(ctx.D).dtype != dtype:
            raise ValueError(
                "stacked lanes must share the distance dtype: "
                f"{np.asarray(ctx.D).dtype} != {dtype}"
            )
    T = len(lanes)
    Tb = bucket_pow2(T)
    Bt = bucket_pow2(max(len(specs) for _ctx, specs in lanes))
    kmax = bucket_pow2(
        max((s.k for _ctx, specs in lanes for s in specs), default=1)
    )
    hs = [
        ctx.spec.num_categories if ctx.spec.kind == "partition" else 1
        for ctx, _specs in lanes
    ]
    hmax = max(hs)
    # padding lanes keep a zero matrix and k=0 rows: the row solver
    # no-ops on them exactly like the pow-2 padding rows it already has
    Ds = np.zeros((Tb, m, m), dtype)
    cats_s = np.zeros((Tb, m), np.int32)
    caps = np.full((Tb, Bt, hmax), m + 1, np.int32)  # padding: uncapped
    allow = np.zeros((Tb, Bt, m), bool)
    ks = np.zeros((Tb, Bt), np.int32)
    gammas = np.zeros((Tb, Bt), np.float32)
    for t, (ctx, specs) in enumerate(lanes):
        Ds[t] = ctx.D
        if ctx.spec.kind == "partition":
            cats_s[t] = np.asarray(ctx.cats[:, 0], np.int32)
            default_caps = ctx.caps
        else:  # uniform: one pseudo-category nobody caps
            default_caps = None
        h = hs[t]
        for i, s in enumerate(specs):
            allow[t, i] = s.allow_mask(m)
            ks[t, i] = s.k
            gammas[t, i] = s.gamma
            if s.caps is not None:
                caps[t, i, :h] = np.asarray(s.caps, np.int32)
            elif default_caps is not None:
                caps[t, i, :h] = default_caps
    with obs.compile_region(
        f"solve[jit_sum_stacked T={Tb} B={Bt} kmax={kmax} m={m}]"
    ):
        sel, nsel, _div = solve_sum_batch_stacked(
            jnp.asarray(Ds),
            jnp.asarray(cats_s),
            jnp.asarray(caps),
            jnp.asarray(allow),
            jnp.asarray(ks),
            jnp.asarray(gammas),
            kmax=kmax,
        )
    sel, nsel = np.asarray(sel), np.asarray(nsel)
    out: list[list[EngineSolution]] = []
    for t, (ctx, specs) in enumerate(lanes):
        sols = []
        for i, s in enumerate(specs):
            loc = sel[t, i, : nsel[t, i]].astype(np.int64)
            # same contract as the per-tenant engine: the f32 objective
            # the kernel accumulated is discarded, the canonical f64
            # value is recomputed from the indices it decided on
            sols.append(
                EngineSolution(
                    local_indices=loc,
                    value=selection_value(ctx.D, loc, s.variant),
                    engine="jit_sum",
                )
            )
        out.append(sols)
    return out
