"""Host (numpy) reference engines — the paper's final-stage solvers.

These are the parity anchors of the registry: every other engine's
``exact_parity`` claim is "same selections as the host engine on the same
matrix". They need a host matroid oracle (``ctx.matroid_fn``), so they
cover *every* matroid kind, including general oracles no jit engine can.

* ``host_local_search`` — AMT local search (footnote 5), sum variant,
  any matroid.
* ``host_exhaustive`` — exact DFS with matroid pruning (§4.4), the
  star/tree/cycle/bipartition variants, any matroid.

``engine="host"`` (the pre-registry spelling) resolves to whichever of
the two covers the requested variant — i.e. exactly the historical
``final_solve`` dispatch.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from ..diversity import Variant
from .base import (
    EngineSolution,
    SolveContext,
    SolveSpec,
    SolverEngine,
    selection_value,
)
from .exhaustive import exhaustive_best
from .local_search import local_search_sum


def _require_matroid(ctx: SolveContext, engine: str):
    if ctx.matroid_fn is None:
        raise ValueError(
            f"engine {engine!r} needs a host matroid oracle "
            f"(SolveContext.matroid_fn)"
        )
    return ctx.matroid_fn


class HostLocalSearchEngine(SolverEngine):
    """AMT local search on the precomputed coreset matrix (sum only)."""

    name = "host_local_search"
    priority = 90
    exact_parity = True  # it IS the reference

    def supports(self, variant: Variant, matroid_kind: str) -> bool:
        return variant == "sum"

    def eligible(self, ctx: SolveContext, spec: SolveSpec) -> bool:
        return (
            self.supports(spec.variant, ctx.spec.kind)
            and ctx.matroid_fn is not None
        )

    def solve_one(self, ctx: SolveContext, spec: SolveSpec) -> EngineSolution:
        matroid = _require_matroid(ctx, self.name)(spec)
        idxs = spec.candidate_idxs(ctx.size)
        X, _val, _swaps = local_search_sum(
            ctx.D, matroid, spec.k, idxs, gamma=spec.gamma
        )
        return EngineSolution(
            local_indices=np.asarray(X, np.int64),
            value=selection_value(ctx.D, X, spec.variant),
            engine=self.name,
        )


class HostExhaustiveEngine(SolverEngine):
    """Exact DFS over independent sets (non-sum variants)."""

    name = "host_exhaustive"
    priority = 95
    exact_parity = True

    def supports(self, variant: Variant, matroid_kind: str) -> bool:
        return variant != "sum"

    def eligible(self, ctx: SolveContext, spec: SolveSpec) -> bool:
        return (
            self.supports(spec.variant, ctx.spec.kind)
            and ctx.matroid_fn is not None
        )

    def solve_one(self, ctx: SolveContext, spec: SolveSpec) -> EngineSolution:
        matroid = _require_matroid(ctx, self.name)(spec)
        idxs = spec.candidate_idxs(ctx.size)
        X, _val, _complete = exhaustive_best(
            ctx.D, matroid, spec.k, idxs, spec.variant
        )
        return EngineSolution(
            local_indices=np.asarray(X, np.int64),
            value=selection_value(ctx.D, X, spec.variant),
            engine=self.name,
        )
