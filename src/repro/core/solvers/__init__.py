"""Pluggable final-stage solver engines (§4.4 behind one seam).

Importing this package registers the built-in engines:

    jit_sum           vmapped batched sum solver — uniform/partition/
                      transversal matroids; host-parity
    jit_greedy        vmapped batched star/tree greedy — approximate,
                      explicit opt-in only (engine=/engine_hint=)
    host_local_search AMT local search, sum under any matroid (reference)
    host_exhaustive   exact DFS, non-sum variants under any matroid
                      (reference)

``select_engine`` implements ``engine="auto"`` (fastest eligible engine
with the host-parity guarantee); ``register_engine`` accepts custom
engines (see README "Solver engines").
"""
from .base import (
    MATROID_KINDS,
    EngineSolution,
    SolveContext,
    SolveSpec,
    SolverEngine,
    coverage_matrix,
    get_engine,
    partition_by_engine,
    register_engine,
    registered_engines,
    resolve_engine,
    select_engine,
    selection_value,
)
from .cost_model import CostModel, EngineSeed, default_cost_model
from .exhaustive import exhaustive_best
from .host import HostExhaustiveEngine, HostLocalSearchEngine
from .jit_greedy import (
    JitGreedyBatchEngine,
    solve_greedy_batch,
    solve_greedy_batch_transversal,
)
from .jit_sum import (
    JitSumBatchEngine,
    bucket_pow2,
    solve_sum_batch,
    solve_sum_batch_transversal,
)
from .local_search import greedy_init, local_search_sum
from .stacked import (
    counts_stack_eligible,
    solve_stacked,
    solve_sum_batch_stacked,
)

HOST_LOCAL_SEARCH = register_engine(HostLocalSearchEngine())
HOST_EXHAUSTIVE = register_engine(HostExhaustiveEngine())
JIT_SUM = register_engine(JitSumBatchEngine())
JIT_GREEDY = register_engine(JitGreedyBatchEngine())

__all__ = [
    "MATROID_KINDS", "EngineSolution", "SolveContext", "SolveSpec",
    "SolverEngine", "coverage_matrix", "get_engine", "partition_by_engine",
    "register_engine", "registered_engines", "resolve_engine",
    "select_engine", "selection_value",
    "CostModel", "EngineSeed", "default_cost_model",
    "HostExhaustiveEngine", "HostLocalSearchEngine",
    "JitGreedyBatchEngine", "JitSumBatchEngine",
    "bucket_pow2", "solve_sum_batch", "solve_sum_batch_transversal",
    "solve_greedy_batch", "solve_greedy_batch_transversal",
    "counts_stack_eligible", "solve_stacked", "solve_sum_batch_stacked",
    "exhaustive_best", "greedy_init", "local_search_sum",
]
