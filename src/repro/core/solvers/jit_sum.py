"""Vectorized batched sum-variant engine (uniform/partition/transversal).

``solve_sum_batch`` answers a batch of heterogeneous sum-diversity queries
(per-query k, category caps, candidate filters) against ONE cached coreset
distance matrix: a vmapped greedy seeding + masked first-improvement local
search, mirroring ``solvers.local_search.local_search_sum`` step for step
(same greedy gains, same (v, u) scan order, same incremental swap value, X
kept in insertion order) so the fast path lands on the same local optimum
as the host solver on the same matrix.

Matroid feasibility inside the greedy/swap loops comes in two flavours,
chosen statically per matroid kind:

* uniform/partition — the O(1) ``counts < caps`` check (uniform is a
  single pseudo-category nobody caps);
* transversal — the masked augmenting-path primitives of
  ``solvers.matching``: "can candidate v extend (or swap into) the current
  selection" is answered exactly, by the same alternating-path truth the
  host oracle computes, so accept/reject decisions are identical to
  ``local_search_sum`` under a ``TransversalMatroid``.

Everything is masked to static shapes: queries are padded to the batch's
``kmax`` (bucketed to the next power of two so novel max-k values don't
recompile) and the batch to a power-of-two length; infeasible queries
simply stop early (nsel < k) like the host solver does.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ... import obs
from ..diversity import Variant
from .base import (
    EngineSolution,
    SolveContext,
    SolveSpec,
    SolverEngine,
    selection_value,
)
from .matching import augment, cats_onehot, feasible_all, swap_feasible


def bucket_pow2(n: int) -> int:
    """Next power of two >= n (>= 1). Shape-bucketing for the jit cache:
    a batch of 5 queries with max k 6 compiles the (8, 8) kernel, and any
    later batch with B <= 8, k <= 8 reuses it."""
    return 1 << max(0, int(n - 1).bit_length())


def jit_cell_eligible(
    engine: SolverEngine, ctx: SolveContext, spec: SolveSpec
) -> bool:
    """Data-dependent eligibility shared by the jit batch engines."""
    if not engine.supports(spec.variant, ctx.spec.kind):
        return False
    if not spec.ascending_candidates(ctx.size):
        return False  # custom candidate order is host-solver territory
    if ctx.spec.kind != "uniform" and ctx.cats is None:
        return False  # jit path needs the category matrix
    if ctx.spec.kind == "partition":
        # a partition matroid is single-label by definition; rows with a
        # second real label must go to the host oracle, which raises the
        # descriptive error (never truncate silently)
        if ctx.partition_multilabel():
            return False
        if ctx.caps is None and spec.caps is None:
            return False
    return True


def pad_query_arrays(
    ctx: SolveContext, specs: Sequence[SolveSpec], Bb: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(allow (Bb, m), ks (Bb,), gammas (Bb,)) with power-of-two padding
    rows that solve k=0 no-op queries."""
    m = ctx.size
    allow_b = np.zeros((Bb, m), bool)
    ks = np.zeros((Bb,), np.int32)
    gammas = np.zeros((Bb,), np.float32)
    for i, s in enumerate(specs):
        allow_b[i] = s.allow_mask(m)
        ks[i] = s.k
        gammas[i] = s.gamma
    return allow_b, ks, gammas


def partition_arrays(
    ctx: SolveContext, specs: Sequence[SolveSpec], Bb: int
) -> tuple[np.ndarray, np.ndarray]:
    """(cats1 (m,), caps_b (Bb, h)) for the counts<caps feasibility path;
    uniform matroids become one pseudo-category nobody caps."""
    m = ctx.size
    if ctx.spec.kind == "partition":
        cats1 = np.asarray(ctx.cats[:, 0], np.int32)
        h = ctx.spec.num_categories
        default_caps = ctx.caps
    else:  # uniform
        cats1 = np.zeros((m,), np.int32)
        h = 1
        default_caps = None
    caps_b = np.full((Bb, h), m + 1, np.int32)  # padding rows: uncapped
    for i, s in enumerate(specs):
        if s.caps is not None:
            caps_b[i] = np.asarray(s.caps, np.int32)
        elif default_caps is not None:
            caps_b[i] = default_caps
    return cats1, caps_b


# --------------------------------------------------------------------------
# uniform / partition: counts-based feasibility (historical fast path)
# --------------------------------------------------------------------------


def _greedy_seed(D, cats, caps, allow, k, kmax):
    """Mirror of local_search.greedy_init: max marginal-gain candidate per
    step (first index wins ties), partition feasibility via counts<caps."""
    m = D.shape[0]
    h = caps.shape[0]
    rowsum_all = jnp.sum(D, axis=1)  # gain of the very first pick

    def body(i, carry):
        sel, selmask, counts, nsel = carry
        can = allow & ~selmask & (counts[cats] < caps[cats])
        gains = jnp.where(
            nsel == 0, rowsum_all, D @ selmask.astype(jnp.float32)
        )
        v = jnp.argmax(jnp.where(can, gains, -jnp.inf))
        take = (i < k) & jnp.any(can)

        def add(c):
            sel, selmask, counts, nsel = c
            return (
                sel.at[nsel].set(v),
                selmask.at[v].set(True),
                counts.at[cats[v]].add(1),
                nsel + 1,
            )

        return jax.lax.cond(take, add, lambda c: c, carry)

    init = (
        jnp.full((kmax,), -1, jnp.int32),
        jnp.zeros((m,), bool),
        jnp.zeros((h,), jnp.int32),
        jnp.int32(0),
    )
    return jax.lax.fori_loop(0, kmax, body, init)


def _solve_sum_one(D, cats, caps, allow, k, gamma, *, kmax, max_sweeps):
    """Single-query greedy + first-improvement local search over cached D."""
    m = D.shape[0]
    sel, selmask, counts, nsel = _greedy_seed(D, cats, caps, allow, k, kmax)
    selm_f = selmask.astype(jnp.float32)
    div0 = 0.5 * jnp.dot(selm_f, D @ selm_f)
    slots = jnp.arange(kmax, dtype=jnp.int32)

    def v_body(v, st):
        sel, selmask, counts, rowX, div, improved = st
        u = jnp.maximum(sel, 0)  # (kmax,) slot -> local id (garbage past k)
        # div(X - u + v) = div - row[u] + dv - d(u, v)   (host's identity)
        new_div = div - rowX[u] + rowX[v] - D[u, v]
        cat_v = cats[v]
        ok_cap = counts[cat_v] - (cats[u] == cat_v) + 1 <= caps[cat_v]
        improving = (
            (slots < nsel)
            & (new_div > div * (1.0 + gamma))
            & (new_div > div)
            & ok_cap
        )
        any_imp = allow[v] & ~selmask[v] & jnp.any(improving)
        ui = jnp.argmax(improving)  # first improving u in X order

        def do_swap(st):
            sel, selmask, counts, rowX, div, improved = st
            uold = sel[ui]
            # host order: X = [w for w in X if w != u] + [v]
            src = jnp.where(slots >= ui, jnp.minimum(slots + 1, kmax - 1), slots)
            sel2 = sel[src].at[nsel - 1].set(v)
            selmask2 = selmask.at[uold].set(False).at[v].set(True)
            counts2 = counts.at[cats[uold]].add(-1).at[cat_v].add(1)
            rowX2 = D @ selmask2.astype(jnp.float32)
            return sel2, selmask2, counts2, rowX2, new_div[ui], True

        return jax.lax.cond(any_imp, do_swap, lambda s: s, st)

    def sweep_cond(carry):
        st, sweeps = carry
        return st[-1] & (sweeps < max_sweeps)

    def sweep_body(carry):
        st, sweeps = carry
        st = (*st[:-1], False)
        st = jax.lax.fori_loop(0, m, v_body, st)
        return st, sweeps + 1

    rowX0 = D @ selm_f
    ls0 = ((sel, selmask, counts, rowX0, div0, nsel == k), jnp.int32(0))
    (sel, selmask, counts, _rowX, div, _imp), _ = jax.lax.while_loop(
        sweep_cond, sweep_body, ls0
    )
    return sel, nsel, div


@functools.partial(jax.jit, static_argnames=("kmax", "max_sweeps"))
def solve_sum_batch(
    D: jnp.ndarray,  # (m, m) cached coreset distances
    cats: jnp.ndarray,  # (m,) int32 single-label categories (zeros: uniform)
    caps: jnp.ndarray,  # (B, h) per-query caps
    allow: jnp.ndarray,  # (B, m) per-query candidate masks
    ks: jnp.ndarray,  # (B,)
    gammas: jnp.ndarray,  # (B,)
    *,
    kmax: int,
    max_sweeps: int = 64,
):
    """Batch of sum-DMMC queries on one matrix (uniform/partition).
    Returns (sel (B, kmax) local ids -1-padded, nsel (B,), div (B,))."""
    f = functools.partial(_solve_sum_one, kmax=kmax, max_sweeps=max_sweeps)
    with jax.named_scope("solver/jit_sum"):
        return jax.vmap(f, in_axes=(None, None, 0, 0, 0, 0))(
            D, cats, caps, allow, ks, gammas
        )


# --------------------------------------------------------------------------
# transversal: augmenting-path feasibility
# --------------------------------------------------------------------------


def _greedy_seed_tv(D, oh, allow, k, kmax):
    """Greedy seeding under a transversal matroid: same gains/tie-breaks
    as ``_greedy_seed``, feasibility = augmenting path exists (exact)."""
    m = D.shape[0]
    h = oh.shape[1]
    rowsum_all = jnp.sum(D, axis=1)

    def body(i, carry):
        sel, selmask, ms_pt, nsel = carry
        can = allow & ~selmask & feasible_all(oh, ms_pt, kmax)
        gains = jnp.where(
            nsel == 0, rowsum_all, D @ selmask.astype(jnp.float32)
        )
        v = jnp.argmax(jnp.where(can, gains, -jnp.inf))
        take = (i < k) & jnp.any(can)

        def add(c):
            sel, selmask, ms_pt, nsel = c
            return (
                sel.at[nsel].set(v),
                selmask.at[v].set(True),
                augment(oh, ms_pt, v, kmax),
                nsel + 1,
            )

        return jax.lax.cond(take, add, lambda c: c, carry)

    init = (
        jnp.full((kmax,), -1, jnp.int32),
        jnp.zeros((m,), bool),
        jnp.full((h,), -1, jnp.int32),
        jnp.int32(0),
    )
    return jax.lax.fori_loop(0, kmax, body, init)


def _solve_sum_one_tv(D, oh, allow, k, gamma, *, kmax, max_sweeps):
    """Single transversal sum query: greedy + first-improvement local
    search, swap feasibility via masked augmenting paths. Mirrors
    ``local_search_sum`` under a ``TransversalMatroid`` decision for
    decision (feasibility truth is matching-independent)."""
    m = D.shape[0]
    sel, selmask, ms_pt, nsel = _greedy_seed_tv(D, oh, allow, k, kmax)
    selm_f = selmask.astype(jnp.float32)
    div0 = 0.5 * jnp.dot(selm_f, D @ selm_f)
    slots = jnp.arange(kmax, dtype=jnp.int32)

    def v_body(v, st):
        sel, selmask, ms_pt, rowX, div, improved = st
        u = jnp.maximum(sel, 0)
        new_div = div - rowX[u] + rowX[v] - D[u, v]
        ok_swap = swap_feasible(oh, ms_pt, sel, v)  # (kmax,) exact
        improving = (
            (slots < nsel)
            & (new_div > div * (1.0 + gamma))
            & (new_div > div)
            & ok_swap
        )
        any_imp = allow[v] & ~selmask[v] & jnp.any(improving)
        ui = jnp.argmax(improving)

        def do_swap(st):
            sel, selmask, ms_pt, rowX, div, improved = st
            uold = sel[ui]
            src = jnp.where(slots >= ui, jnp.minimum(slots + 1, kmax - 1), slots)
            sel2 = sel[src].at[nsel - 1].set(v)
            selmask2 = selmask.at[uold].set(False).at[v].set(True)
            # rebuild the matching: free u's category, re-insert v
            ms2 = jnp.where(ms_pt == uold, jnp.int32(-1), ms_pt)
            ms2 = augment(oh, ms2, v, kmax)
            rowX2 = D @ selmask2.astype(jnp.float32)
            return sel2, selmask2, ms2, rowX2, new_div[ui], True

        return jax.lax.cond(any_imp, do_swap, lambda s: s, st)

    def sweep_cond(carry):
        st, sweeps = carry
        return st[-1] & (sweeps < max_sweeps)

    def sweep_body(carry):
        st, sweeps = carry
        st = (*st[:-1], False)
        st = jax.lax.fori_loop(0, m, v_body, st)
        return st, sweeps + 1

    rowX0 = D @ selm_f
    ls0 = ((sel, selmask, ms_pt, rowX0, div0, nsel == k), jnp.int32(0))
    (sel, _selmask, _ms, _rowX, div, _imp), _ = jax.lax.while_loop(
        sweep_cond, sweep_body, ls0
    )
    return sel, nsel, div


@functools.partial(jax.jit, static_argnames=("kmax", "max_sweeps"))
def solve_sum_batch_transversal(
    D: jnp.ndarray,  # (m, m)
    oh: jnp.ndarray,  # (m, h) bool point-category incidence
    allow: jnp.ndarray,  # (B, m)
    ks: jnp.ndarray,  # (B,)
    gammas: jnp.ndarray,  # (B,)
    *,
    kmax: int,
    max_sweeps: int = 64,
):
    """Batch of sum-DMMC queries under ONE transversal matroid.
    Returns (sel (B, kmax) -1-padded, nsel (B,), div (B,))."""
    f = functools.partial(_solve_sum_one_tv, kmax=kmax, max_sweeps=max_sweeps)
    with jax.named_scope("solver/jit_sum_tv"):
        return jax.vmap(f, in_axes=(None, None, 0, 0, 0))(
            D, oh, allow, ks, gammas
        )


# --------------------------------------------------------------------------
# engine
# --------------------------------------------------------------------------


class JitSumBatchEngine(SolverEngine):
    """Registry face of the two batched jit solvers above."""

    name = "jit_sum"
    priority = 10
    exact_parity = True  # mirrors host local search step for step

    def supports(self, variant: Variant, matroid_kind: str) -> bool:
        return variant == "sum" and matroid_kind in (
            "uniform", "partition", "transversal"
        )

    def eligible(self, ctx: SolveContext, spec: SolveSpec) -> bool:
        return jit_cell_eligible(self, ctx, spec)

    def stack_eligible(self, ctx: SolveContext, spec: SolveSpec) -> bool:
        # local import: stacked.py reuses this module's row solver
        from .stacked import counts_stack_eligible

        return counts_stack_eligible(self, ctx, spec)

    def solve_batch_stacked(self, lanes) -> "list[list[EngineSolution]]":
        from .stacked import solve_stacked

        return solve_stacked(lanes)

    def solve_batch(
        self, ctx: SolveContext, specs: Sequence[SolveSpec]
    ) -> list[EngineSolution]:
        Bb = bucket_pow2(len(specs))
        kmax = bucket_pow2(max((s.k for s in specs), default=1))
        allow_b, ks, gammas = pad_query_arrays(ctx, specs, Bb)

        if ctx.spec.kind == "transversal":
            oh = cats_onehot(ctx.cats, ctx.spec.num_categories)
            with obs.compile_region(
                f"solve[jit_sum_tv B={Bb} kmax={kmax} m={ctx.size}]"
            ):
                sel, nsel, _div = solve_sum_batch_transversal(
                    jnp.asarray(ctx.D),
                    jnp.asarray(oh),
                    jnp.asarray(allow_b),
                    jnp.asarray(ks),
                    jnp.asarray(gammas),
                    kmax=kmax,
                )
        else:
            cats1, caps_b = partition_arrays(ctx, specs, Bb)
            with obs.compile_region(
                f"solve[jit_sum B={Bb} kmax={kmax} m={ctx.size}]"
            ):
                sel, nsel, _div = solve_sum_batch(
                    jnp.asarray(ctx.D),
                    jnp.asarray(cats1),
                    jnp.asarray(caps_b),
                    jnp.asarray(allow_b),
                    jnp.asarray(ks),
                    jnp.asarray(gammas),
                    kmax=kmax,
                )

        sel, nsel = np.asarray(sel), np.asarray(nsel)
        out = []
        for i, s in enumerate(specs):
            loc = sel[i, : nsel[i]].astype(np.int64)
            # the jit solver accumulates its objective in f32; the indices
            # are what it decided on — report the canonical f64 value
            out.append(
                EngineSolution(
                    local_indices=loc,
                    value=selection_value(ctx.D, loc, s.variant),
                    engine=self.name,
                )
            )
        return out
