"""Calibrated engine cost model: what will this solve_batch call cost?

``engine="auto"`` used to mean "the fastest eligible parity engine" with
*fastest* hard-coded as a priority integer on each engine class. That
ordering encodes one machine's folklore: jit engines amortize one device
dispatch over the whole vmapped batch, host engines pay pure-python cost
per query but no dispatch — so the truth is a crossover, not a ranking.
Which side of the crossover a request lands on depends on the batch size
``B``, the (bucketed) ``kmax`` and the coreset size ``m``, and on what
the hardware actually measures — exactly the solver-selection tradeoff
Cevallos et al. frame for the convex/local-search engines.

``CostModel.estimate(engine, B, kmax, m)`` predicts the wall seconds of
one ``solve_batch`` call:

* **static seeds** — per-engine parametric models
  ``dispatch + B * per_query(kmax, m)`` whose constants are calibrated
  offline against the committed ``BENCH_serve.json`` per-engine QPS
  numbers (CPU host). They reproduce the historical priority ordering at
  bench scale and put the host/jit crossover where dispatch genuinely
  dominates (tiny ``B`` x small ``m``);
* **online refinement** — every measured solve feeds
  ``observe(engine, B, kmax, m, seconds)`` (the serving frontend calls
  it with the same wall it records into the PR 6 latency histograms); an
  EMA per pow-2-bucketed ``(engine, B, kmax, m)`` cell overrides the
  seed, and near-miss cells extrapolate from the nearest measured ``B``
  bucket along the seed model's shape. The crossover is *measured*, not
  asserted — ``crossover()`` reports where it currently sits.

Routing decisions made from these estimates are recorded in a bounded
ring (``decisions()``) with the per-engine estimates that drove them, so
``engine="auto"`` is auditable after the fact.

Thread-safe; one instance per ``QueryFrontend`` (a process-global
``default_cost_model()`` exists for registry-level callers).
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import math
import threading
from typing import Optional, Sequence

_log = logging.getLogger(__name__)

# EMA weight of one new observation against the cell's running estimate
_ALPHA = 0.25
# decision audit ring size
_DECISIONS = 256


def _bucket_pow2(n: int) -> int:
    """Next power of two >= n (>= 1) — the same shape bucketing the jit
    solvers use, so cost cells and compile-cache keys line up."""
    return 1 << max(0, int(n - 1).bit_length())


@dataclasses.dataclass(frozen=True)
class EngineSeed:
    """Static parametric prior for one engine:
    ``t(B) = dispatch_s + B * (per_query_s + coef_s * m**m_exp * min(kmax, k_cap))``.

    ``m_exp``/``k_cap`` express how the engine's per-query marginal cost
    scales: the local-search engines sweep the (m, m) matrix per swap
    (quadratic in m, linear in k), exhaustive DFS explodes with k so its
    exponent is k itself, capped to keep the prior finite — past the cap
    the estimate is "always lose", which is the right routing answer.
    """

    dispatch_s: float
    per_query_s: float
    coef_s: float
    m_exp: float = 2.0
    k_cap: int = 64
    k_is_exponent: bool = False

    def per_query(self, kmax: int, m: int) -> float:
        k = min(int(kmax), self.k_cap)
        if self.k_is_exponent:
            return self.per_query_s + self.coef_s * float(m) ** k
        return self.per_query_s + self.coef_s * float(m) ** self.m_exp * k

    def estimate(self, B: int, kmax: int, m: int) -> float:
        return self.dispatch_s + B * self.per_query(kmax, m)


# Seeds calibrated against the committed BENCH_serve.json quick-config
# per-engine QPS (m ~= 43, kmax <= 8, CPU host):
#   jit_sum   4530 qps @ B=32 -> ~7 ms/batch, dispatch-dominated
#   host_ls    363 qps @ B=32 -> ~2.8 ms/query, no meaningful dispatch
#   jit_greedy 2481 qps @ B=8 -> ~3.2 ms/batch
#   host_exh   2.8 qps @ B=8, k=3 -> ~0.36 s/query (C(m,k) DFS)
_SEEDS: dict[str, EngineSeed] = {
    "jit_sum": EngineSeed(
        dispatch_s=2.0e-3, per_query_s=5.0e-5, coef_s=2.0e-9
    ),
    "jit_greedy": EngineSeed(
        dispatch_s=2.0e-3, per_query_s=5.0e-5, coef_s=1.0e-9
    ),
    "host_local_search": EngineSeed(
        dispatch_s=1.0e-4, per_query_s=4.0e-4, coef_s=1.7e-7
    ),
    "host_exhaustive": EngineSeed(
        dispatch_s=1.0e-4, per_query_s=5.0e-4, coef_s=4.0e-6,
        k_cap=4, k_is_exponent=True,
    ),
}
# an engine the seeds don't know (custom registrations): flat per-query
# prior that neither dominates nor vanishes — one observation fixes it
_FALLBACK = EngineSeed(dispatch_s=1.0e-3, per_query_s=1.0e-3, coef_s=0.0)


class CostModel:
    """Seeded + online-refined ``solve_batch`` latency model."""

    def __init__(self, seeds: Optional[dict[str, EngineSeed]] = None):
        self._seeds = dict(_SEEDS if seeds is None else seeds)
        self._mu = threading.Lock()
        # (engine, Bb, kb, mb) -> [ema_seconds, n_observations]
        self._cells: dict[tuple[str, int, int, int], list] = {}
        self._decisions: collections.deque = collections.deque(
            maxlen=_DECISIONS
        )
        self.observations = 0

    # ------------------------------------------------------------------
    # estimation
    # ------------------------------------------------------------------

    def seed(self, engine: str) -> EngineSeed:
        return self._seeds.get(engine, _FALLBACK)

    def _static(self, engine: str, B: int, kmax: int, m: int) -> float:
        return self.seed(engine).estimate(max(1, B), max(1, kmax), max(1, m))

    def estimate(self, engine: str, B: int = 1, kmax: int = 1,
                 m: int = 1) -> float:
        """Predicted wall seconds of one ``solve_batch`` of ``B`` queries
        on ``engine`` (kmax = max selection size in the batch, m =
        coreset rows). Measured cell if one exists; else the nearest
        measured ``B`` bucket extrapolated along the seed shape; else the
        static seed."""
        Bb, kb, mb = _bucket_pow2(B), _bucket_pow2(kmax), _bucket_pow2(m)
        with self._mu:
            cell = self._cells.get((engine, Bb, kb, mb))
            if cell is not None:
                return cell[0]
            # nearest measured B bucket for the same (engine, kmax, m):
            # scale its EMA by the seed model's B-dependence so a B=1
            # measurement still informs a B=16 estimate (and vice versa)
            near = None
            for (e, b2, k2, m2), c in self._cells.items():
                if e == engine and k2 == kb and m2 == mb:
                    d = abs(math.log2(b2) - math.log2(Bb))
                    if near is None or d < near[0]:
                        near = (d, b2, c[0])
        if near is not None:
            _d, b2, ema = near
            base = self._static(engine, b2, kb, mb)
            return ema * (self._static(engine, Bb, kb, mb) / base)
        return self._static(engine, B, kmax, m)

    def estimate_stacked(
        self, engine: str, parts: Sequence[tuple[int, int]], m: int
    ) -> float:
        """Predicted wall seconds of ONE cross-tenant stacked
        ``solve_batch_stacked`` call: ``parts`` is one ``(B, kmax)``
        pair per stacked entry. Rows are vmapped independently and the
        pdist matrix is the only per-entry leaf, so the device sees one
        batch whose effective size is the SUM of rows across entries at
        the max k — pricing it as a single-tenant B would undercount
        the launch by the number of tenants stacked."""
        B = sum(max(1, int(b)) for b, _k in parts)
        kmax = max((max(1, int(k)) for _b, k in parts), default=1)
        return self.estimate(engine, B=B, kmax=kmax, m=m)

    def calibrated(self, engine: str, B: int = 1, kmax: int = 1,
                   m: int = 1) -> bool:
        """True iff ``estimate`` for this request would be backed by at
        least one online observation (any B bucket of the same cell)."""
        kb, mb = _bucket_pow2(kmax), _bucket_pow2(m)
        with self._mu:
            return any(
                e == engine and k2 == kb and m2 == mb
                for (e, _b2, k2, m2) in self._cells
            )

    # ------------------------------------------------------------------
    # online calibration
    # ------------------------------------------------------------------

    def observe(self, engine: str, B: int, kmax: int, m: int,
                seconds: float) -> None:
        """Fold one measured ``solve_batch`` wall into the model."""
        if not (seconds >= 0.0) or B <= 0:  # NaN/negative: refuse quietly
            return
        key = (engine, _bucket_pow2(B), _bucket_pow2(kmax), _bucket_pow2(m))
        with self._mu:
            cell = self._cells.get(key)
            if cell is None:
                self._cells[key] = [float(seconds), 1]
            else:
                cell[0] += _ALPHA * (float(seconds) - cell[0])
                cell[1] += 1
            self.observations += 1

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------

    def choose(self, engines: Sequence[str], B: int, kmax: int,
               m: int) -> tuple[str, dict[str, float]]:
        """argmin-estimate engine for a group of ``B`` requests; ties
        keep the callers' order (which callers pass priority-sorted, so a
        tie preserves the historical policy). Returns the winner and the
        estimates that drove the decision."""
        ests = {e: self.estimate(e, B, kmax, m) for e in engines}
        winner = min(engines, key=lambda e: ests[e])
        return winner, ests

    def record_decision(self, *, engine: str, candidates: dict[str, float],
                        B: int, kmax: int, m: int,
                        stacked: bool = False) -> None:
        d = dict(engine=engine, B=int(B), kmax=int(kmax), m=int(m),
                 stacked=bool(stacked),
                 estimates={k: float(v) for k, v in candidates.items()})
        with self._mu:
            self._decisions.append(d)
        if _log.isEnabledFor(logging.DEBUG):
            _log.debug(
                "cost-model route: %s for B=%d kmax=%d m=%d (%s)",
                engine, B, kmax, m,
                ", ".join(f"{k}={v:.2e}s" for k, v in candidates.items()),
            )

    def decisions(self) -> list[dict]:
        """Most recent ``engine="auto"`` routing decisions (newest last),
        each with the per-candidate estimates that drove it."""
        with self._mu:
            return list(self._decisions)

    def crossover(self, a: str, b: str, *, kmax: int, m: int,
                  max_batch: int = 4096) -> Optional[int]:
        """Smallest pow-2 batch size at which ``a`` is estimated no
        slower than ``b`` (None: ``b`` wins everywhere up to
        ``max_batch``). The operator-facing "where does the jit engine
        start winning" probe the README documents."""
        B = 1
        while B <= max_batch:
            if self.estimate(a, B, kmax, m) <= self.estimate(b, B, kmax, m):
                return B
            B *= 2
        return None

    def snapshot(self) -> dict:
        """Inspection view: observation counts per measured cell plus the
        decision tail (for ``QueryFrontend.stats()``)."""
        with self._mu:
            cells = {
                f"{e}[B={b} kmax={k} m={m}]": {
                    "ema_s": c[0], "n": c[1],
                }
                for (e, b, k, m), c in sorted(self._cells.items())
            }
            return {
                "observations": self.observations,
                "cells": cells,
                "decisions": list(self._decisions)[-8:],
            }


_default: Optional[CostModel] = None
_default_mu = threading.Lock()


def default_cost_model() -> CostModel:
    global _default
    if _default is None:
        with _default_mu:
            if _default is None:
                _default = CostModel()
    return _default
