"""Distance primitives for the DMMC framework.

All pairwise work is phrased as ``||x||^2 + ||y||^2 - 2 x.y`` so the dominant
cost is an MXU-friendly matmul (see kernels/pdist.py for the tiled TPU
version; these jnp forms are the reference / CPU path that ``kernels.ops``
dispatches to off-TPU).

Supported metrics
-----------------
``sqeuclidean``  squared Euclidean (NOT a metric; internal use only — GMM and
                 the coreset radius logic always compare true distances).
``euclidean``    L2 distance.
``cosine``       the *metric* version of cosine distance used by the paper
                 [Leskovec et al.]: we L2-normalize inputs once and use the
                 Euclidean distance on the sphere, which is a metric inducing
                 the same ordering as angular distance.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

Metric = Literal["euclidean", "cosine", "sqeuclidean"]

_EPS = 1e-12


def normalize_for_metric(x: jnp.ndarray, metric: Metric) -> jnp.ndarray:
    """Preprocess points so downstream code can use plain L2 geometry."""
    if metric == "cosine":
        n = jnp.sqrt(jnp.maximum(jnp.sum(x * x, axis=-1, keepdims=True), _EPS))
        return x / n
    return x


def sq_dists(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared Euclidean distances. x: (n, d), y: (m, d) -> (n, m)."""
    xn = jnp.sum(x * x, axis=-1)
    yn = jnp.sum(y * y, axis=-1)
    d2 = xn[:, None] + yn[None, :] - 2.0 * (x @ y.T)
    return jnp.maximum(d2, 0.0)


def dists(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Pairwise Euclidean distances (n, m)."""
    return jnp.sqrt(sq_dists(x, y))


def point_dists(x: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """Distances of every row of x (n, d) to a single point z (d,) -> (n,)."""
    diff = x - z[None, :]
    return jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))


def pairwise_matrix(x: jnp.ndarray) -> jnp.ndarray:
    """Full symmetric distance matrix of a point set (k, d) -> (k, k)."""
    d = dists(x, x)
    # exact zeros on the diagonal despite float error
    return d * (1.0 - jnp.eye(x.shape[0], dtype=d.dtype))


@functools.partial(jax.jit, static_argnames=())
def diameter_lower_bound(x: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """2-approximate diameter: delta = max_j d(x_0, x_j) in [Delta/2, Delta].

    This is the paper's ``delta = d(z1, z2)`` quantity (Alg. 1): the distance
    from an arbitrary anchor to the farthest point.
    """
    big_neg = jnp.asarray(-jnp.inf, x.dtype)
    d0 = point_dists(x, x[0])
    d0 = jnp.where(valid, d0, big_neg)
    return jnp.max(d0)
