"""Int8 error-feedback gradient compression for cross-pod all-reduce.

At 2+ pods the inter-pod links are the scarcest bandwidth; compressing the
pod-level gradient reduction 4x (f32 -> int8 + per-tensor scale) with error
feedback (residual carried into the next step) preserves convergence
(Karimireddy et al., 2019). Wiring:

    comp, new_resid = compress_with_feedback(grad, resid)
    g_pod = psum(comp) over 'pod'  (int8 payload on the wire)
    grad  = decompress(g_pod)

Inside pjit the collective is implicit; ``make_pod_allreduce`` packages the
explicit shard_map version used by the tests and by launch/train.py when
``--compress-pod-grads`` is on.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..compat import axis_size


def quantize(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(
    grads: Any, residual: Any
) -> tuple[Any, Any, Any]:
    """Returns (quantized tree, scales tree, new residual tree)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize(gf)
        deq = dequantize(q, s)
        return q, s, gf - deq

    out = jax.tree.map(one, grads, residual)
    treedef = jax.tree.structure(grads)
    flat = jax.tree.leaves(out, is_leaf=lambda x: isinstance(x, tuple))
    qs = jax.tree.unflatten(treedef, [t[0] for t in flat])
    ss = jax.tree.unflatten(treedef, [t[1] for t in flat])
    rs = jax.tree.unflatten(treedef, [t[2] for t in flat])
    return qs, ss, rs


def init_residual(grads_like: Any) -> Any:
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
    )


def pod_allreduce_compressed(
    grads: Any, residual: Any, axis_name: str = "pod"
) -> tuple[Any, Any]:
    """Error-feedback int8 mean-all-reduce over ``axis_name`` (shard_map).

    All ranks agree on a shared per-tensor scale first (a scalar pmax — a
    negligible collective), so the int8 payloads are additive: psum in int32,
    then one dequantize. Residual = local quantization error, re-injected
    into the next step's gradient (error feedback)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        scale = jnp.maximum(amax / 127.0, 1e-30)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq_local = q.astype(jnp.float32) * scale
        new_r = gf - deq_local
        n = axis_size(axis_name)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return total.astype(jnp.float32) * scale / n, new_r

    out = jax.tree.map(one, grads, residual)
    treedef = jax.tree.structure(grads)
    flat = jax.tree.leaves(out, is_leaf=lambda x: isinstance(x, tuple))
    red = jax.tree.unflatten(treedef, [t[0] for t in flat])
    new_resid = jax.tree.unflatten(treedef, [t[1] for t in flat])
    return red, new_resid
