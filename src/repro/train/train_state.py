"""Train-step builder: microbatched grad accumulation + AdamW + metrics.

``make_train_step(lm, opt_cfg, microbatches=M)`` returns a pure
``(state, batch) -> (state, metrics)`` suitable for jit/pjit. With M > 1 the
global batch is split along the batch axis and scanned, accumulating
gradients in ``accum_dtype`` — this is what bounds activation memory on the
train_4k dry-run cells (remat bounds per-microbatch activations; the scan
bounds the number of live microbatches to one).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..models.model import LM
from .optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class StepConfig:
    microbatches: int = 1
    accum_dtype: str = "float32"
    skip_masked: bool = False  # causal-block-skipping attention (optimized)


def init_train_state(lm: LM, rng, opt_cfg: AdamWConfig) -> dict:
    params = lm.init(rng)
    return {
        "params": params,
        "opt": adamw_init(params, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_train_state(lm: LM, opt_cfg: AdamWConfig, seed: int = 0):
    return jax.eval_shape(
        lambda: init_train_state(lm, jax.random.PRNGKey(seed), opt_cfg)
    )


def make_train_step(
    lm: LM,
    opt_cfg: AdamWConfig,
    step_cfg: StepConfig = StepConfig(),
    grad_specs=None,
):
    """grad_specs: optional PartitionSpec pytree matching params. Pinning the
    gradient (accumulation) sharding to the param sharding is what turns the
    per-microbatch gradient reduction into a reduce-scatter onto the FSDP
    shards instead of a full all-reduce of a replicated buffer (measured
    ~100x collective-byte difference at 394B params — EXPERIMENTS.md §Perf).
    """
    M = step_cfg.microbatches
    adt = jnp.dtype(step_cfg.accum_dtype)

    def pin(tree):
        if grad_specs is None:
            return tree
        return jax.tree.map(
            lambda x, sp: jax.lax.with_sharding_constraint(x, sp),
            tree, grad_specs,
        )

    def loss_fn(params, tokens, img):
        loss, metrics = lm.loss(
            params, tokens, img, skip_masked=step_cfg.skip_masked
        )
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: dict, batch: dict):
        tokens = batch["tokens"]
        img = batch.get("img")
        if M == 1:
            (loss, metrics), grads = grad_fn(state["params"], tokens, img)
            grads = pin(grads)
        else:
            B = tokens.shape[0]
            assert B % M == 0, (B, M)
            mb = B // M
            tok_mb = tokens.reshape(M, mb, *tokens.shape[1:])
            img_mb = (
                img.reshape(M, mb, *img.shape[1:]) if img is not None else None
            )
            zeros = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), state["params"]
            ))

            def mb_step(carry, inp):
                acc, loss_acc = carry
                t = inp["t"]
                i = inp.get("i")
                (loss, _m), g = grad_fn(state["params"], t, i)
                acc = pin(jax.tree.map(
                    lambda a, gg: a + gg.astype(adt) / M, acc, pin(g)
                ))
                return (acc, loss_acc + loss / M), None

            xs = {"t": tok_mb}
            if img_mb is not None:
                xs["i"] = img_mb
            (grads, loss), _ = jax.lax.scan(
                mb_step, (zeros, jnp.zeros((), jnp.float32)), xs
            )
            metrics = dict(ce=loss, aux=jnp.zeros((), jnp.float32))

        new_params, new_opt, stats = adamw_update(
            grads, state["opt"], state["params"], opt_cfg
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        metrics = dict(loss=metrics["ce"], **stats)
        return new_state, metrics

    return train_step
