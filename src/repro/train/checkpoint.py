"""Checkpoint manager: atomic, async, keep-N, elastic.

Layout: <dir>/step_<n>/arrays.npz + manifest.json. Writes go to a temp dir
followed by an atomic os.rename, so a preempted writer can never leave a
half-checkpoint that restore would pick up. An optional background thread
makes ``save`` non-blocking (device->host copy happens synchronously — cheap
relative to disk — the disk write overlaps the next steps).

Elasticity: arrays are stored *unsharded* (gathered to host), so a restore
may target ANY mesh/topology — the caller supplies the new shardings and we
device_put into them. At 1000+-node scale you would write per-host shards
instead; the manifest already records the logical shapes needed to reassemble
(see DESIGN.md §6 — the interface here is what matters for the framework).

Fault-tolerance contract used by launch/train.py:
  * SIGTERM -> finish current step, save, exit 0 (preemption-safe);
  * restart -> ``latest_step`` + ``restore`` resumes bit-exact (data pipeline
    is seekable by step).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype not in (np.float32, np.float64, np.int32, np.int64,
                             np.uint32, np.uint64, np.int8, np.uint8,
                             np.int16, np.uint16, np.bool_):
            # bf16 & friends: store a raw uint16/8 view; the dtype is
            # recovered from the abstract tree at restore time
            arr = arr.view(np.uint8 if arr.itemsize == 1 else np.uint16)
        flat[key] = arr
    return flat


def _unflatten_into(tree: Any, flat: dict[str, np.ndarray]) -> Any:
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in leaves_paths:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        arr = flat[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        want = np.dtype(leaf.dtype)
        if arr.dtype != want:
            arr = arr.view(want) if arr.itemsize == want.itemsize \
                else arr.astype(want)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---- write ----

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> None:
        flat = _flatten(tree)  # sync device->host
        meta = dict(step=int(step), time=time.time(), **(extra or {}))
        self.wait()
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, meta), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, flat, meta)

    def _write(self, step: int, flat: dict, meta: dict) -> None:
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"))

    # ---- read ----

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: int,
        abstract_tree: Any,
        shardings: Optional[Any] = None,
    ) -> Any:
        """Restore into the structure of ``abstract_tree``; if ``shardings``
        (a matching pytree of jax.sharding.Sharding) is given, device_put
        each leaf into it — this is the elastic-remesh path: the target mesh
        may differ arbitrarily from the mesh that wrote the checkpoint."""
        path = os.path.join(self.dir, f"step_{step:010d}", "arrays.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(abstract_tree, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda leaf, sh: jax.device_put(leaf, sh), tree, shardings
            )
        return tree
