"""AdamW with scale-friendly memory knobs (no external deps).

Knobs that matter at 256-512 chips:
* ``moment_dtype`` — bf16 moments halve optimizer HBM (the default for the
  >100B configs in the dry-run; f32 for real small-scale training);
* ``master_dtype`` — optional f32 master copy of bf16 params (accuracy) or
  None to update bf16 params directly via an f32 compute path (memory);
* global-norm clipping computed in f32 across the sharded tree (one small
  all-reduce, fused by XLA with the gradient reduction).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    master_dtype: Optional[str] = None  # "float32" to keep a master copy
    warmup_steps: int = 100
    schedule: str = "cosine"  # cosine | constant
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        return cfg.lr * warm
    t = jnp.clip(
        (s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.master_dtype is not None:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.dtype(cfg.master_dtype)), params
        )
    return state


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves)
    )


def adamw_update(
    grads: Any, state: dict, params: Any, cfg: AdamWConfig
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    src = state.get("master", params)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mf = m.astype(jnp.float32) * b1 + g * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + g * g * (1 - b2)
        mhat = mf / bc1
        vhat = vf / bc2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                        + cfg.weight_decay * pf * (p.ndim >= 2))
        return pf, mf.astype(mdt), vf.astype(mdt)

    out = jax.tree.map(upd, src, grads, state["m"], state["v"])
    treedef = jax.tree.structure(params)
    flat = jax.tree.leaves(out, is_leaf=lambda x: isinstance(x, tuple))
    pf_leaves = [t[0] for t in flat]
    m_leaves = [t[1] for t in flat]
    v_leaves = [t[2] for t in flat]
    param_dtypes = [l.dtype for l in jax.tree.leaves(params)]
    new_params = jax.tree.unflatten(
        treedef, [pf.astype(dt) for pf, dt in zip(pf_leaves, param_dtypes)]
    )
    new_state = {
        "m": jax.tree.unflatten(treedef, m_leaves),
        "v": jax.tree.unflatten(treedef, v_leaves),
        "step": step,
    }
    if "master" in state:
        new_state["master"] = jax.tree.unflatten(
            treedef,
            [pf.astype(jnp.dtype(cfg.master_dtype)) for pf in pf_leaves],
        )
    stats = dict(grad_norm=gnorm, lr=lr)
    return new_params, new_state, stats
