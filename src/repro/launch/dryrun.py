import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract the roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Per cell this prints/records:
  * compiled.memory_analysis()  (per-device bytes: args/output/temps)
  * compiled.cost_analysis()    (per-device HLO FLOPs / bytes accessed)
  * collective operand bytes parsed from the post-opt HLO (per device)
  * the three roofline terms + dominant bottleneck + MODEL_FLOPS ratio

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI. cost_analysis/memory_analysis were verified per-device (see
EXPERIMENTS.md §Dry-run calibration note).
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from ..models.model import LM
from ..models.sharding import (
    batch_spec, cache_specs, param_specs, set_activation_mesh,
)
from ..train.optimizer import AdamWConfig
from ..train.train_state import StepConfig, abstract_train_state, make_train_step
from .hlo_cost import analyze as hlo_analyze
from .mesh import data_axes, make_production_mesh

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link


# --------------------------------------------------------------------------
# per-cell configuration policy (memory knobs; see EXPERIMENTS.md §Dry-run)
# --------------------------------------------------------------------------


def knobs_for(cfg, shape, n_dp: int, overrides: dict):
    lm = LM(cfg)
    n_params = lm.param_count()
    big = n_params > 3e10
    micro = overrides.get("microbatches")
    if micro is None:
        if shape.kind == "train" and n_params > 2e9:
            micro = max(1, shape.global_batch // n_dp)
        else:
            micro = 1
    opt = AdamWConfig(
        moment_dtype=overrides.get(
            "moment_dtype", "bfloat16" if big else "float32"
        ),
        master_dtype=overrides.get(
            "master_dtype", None if big else "float32"
        ),
    )
    step = StepConfig(
        microbatches=micro,
        accum_dtype=overrides.get(
            "accum_dtype", "bfloat16" if big else "float32"
        ),
        skip_masked=overrides.get("skip_masked", False),
    )
    return lm, opt, step


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# --------------------------------------------------------------------------


def input_specs(cfg, shape, lm: LM):
    B, S = shape.global_batch, shape.seq_len
    toks = jax.ShapeDtypeStruct(
        (B, S if shape.kind != "decode" else 1), jnp.int32
    )
    img = None
    if cfg.family == "vlm":
        img = jax.ShapeDtypeStruct(
            (B, cfg.n_img_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    if shape.kind == "decode":
        caches = jax.eval_shape(lambda: lm.init_caches(B, S))
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        return dict(token=toks, caches=caches, pos=pos, img=img)
    return dict(tokens=toks, img=img)


# --------------------------------------------------------------------------
# lowering per shape kind
# --------------------------------------------------------------------------


def build_lowered(cfg, shape, mesh, overrides):
    fsdp = data_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in fsdp]))
    lm, opt_cfg, step_cfg = knobs_for(cfg, shape, n_dp, overrides)
    shardable = shape.global_batch % n_dp == 0 and shape.global_batch >= n_dp
    set_activation_mesh(fsdp if shardable else None, "model")
    bspec = batch_spec(shardable, fsdp)
    pspecs = param_specs(lm.abstract_params(), fsdp)

    def ns(tree):
        """PartitionSpec pytree -> NamedSharding pytree on this mesh."""
        return jax.tree.map(
            lambda sp: NamedSharding(mesh, sp) if isinstance(sp, P) else sp,
            tree,
            is_leaf=lambda x: isinstance(x, P) or x is None,
        )

    def shard(tree, specs):
        return jax.tree.map(
            lambda leaf, sp: jax.ShapeDtypeStruct(
                leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, sp)
            ),
            tree, specs,
        )

    if shape.kind == "train":
        state_abs = abstract_train_state(lm, opt_cfg)
        opt_specs = {
            "m": pspecs, "v": pspecs, "step": P(),
        }
        if "master" in state_abs["opt"]:
            opt_specs["master"] = pspecs
        state_specs = {"params": pspecs, "opt": opt_specs, "step": P()}
        ins = input_specs(cfg, shape, lm)
        batch_abs = {"tokens": ins["tokens"]}
        batch_specs = {"tokens": bspec}
        if ins["img"] is not None:
            batch_abs["img"] = ins["img"]
            batch_specs["img"] = P(*bspec, None, None)
        fn = make_train_step(lm, opt_cfg, step_cfg, grad_specs=pspecs)
        jfn = jax.jit(
            fn,
            in_shardings=ns((state_specs, batch_specs)),
            out_shardings=ns((state_specs, None)),
            donate_argnums=(0,),
        )
        with mesh:
            lowered = jfn.lower(shard(state_abs, state_specs),
                                shard(batch_abs, batch_specs))
        return lm, lowered, dict(microbatches=step_cfg.microbatches)

    if shape.kind == "prefill":
        ins = input_specs(cfg, shape, lm)
        args = (ins["tokens"],) + (
            (ins["img"],) if ins["img"] is not None else ()
        )
        in_sh = (bspec,) + ((P(*bspec, None, None),) if ins["img"] is not None else ())
        cspecs = cache_specs(
            lm, fsdp, batch_shardable=shardable,
            mode=overrides.get("cache_shard", "auto"),
            tp_size=mesh.shape["model"],
        )
        out_sh = (P(*bspec, None), cspecs)

        def prefill(params, tokens, img=None):
            return lm.prefill(params, tokens, img)

        jfn = jax.jit(
            prefill,
            in_shardings=ns((pspecs,) + in_sh),
            out_shardings=ns(out_sh),
        )
        with mesh:
            lowered = jfn.lower(
                shard(lm.abstract_params(), pspecs), *args
            )
        return lm, lowered, {}

    if shape.kind == "decode":
        ins = input_specs(cfg, shape, lm)
        cspecs = cache_specs(
            lm, fsdp, batch_shardable=shardable,
            mode=overrides.get("cache_shard", "auto"),
            tp_size=mesh.shape["model"],
        )

        def serve_step(params, token, caches, pos, img=None):
            return lm.decode_step(params, token, caches, pos, img)

        img_args = (ins["img"],) if ins["img"] is not None else ()
        img_specs = (P(*bspec, None, None),) if ins["img"] is not None else ()
        jfn = jax.jit(
            serve_step,
            in_shardings=ns((pspecs, bspec, cspecs, P()) + img_specs),
            out_shardings=ns((P(*bspec, None), cspecs)),
            donate_argnums=(2,),
        )
        with mesh:
            lowered = jfn.lower(
                shard(lm.abstract_params(), pspecs),
                ins["token"], shard(ins["caches"], cspecs), ins["pos"],
                *img_args,
            )
        return lm, lowered, {}

    raise ValueError(shape.kind)


# --------------------------------------------------------------------------
# MODEL_FLOPS (useful flops) estimator
# --------------------------------------------------------------------------


def model_flops(cfg, shape, lm: LM) -> float:
    B, S = shape.global_batch, shape.seq_len
    n_active = lm.active_param_count()
    d_inner = cfg.ssm_expand * cfg.d_model
    n_ssm_heads = d_inner // cfg.ssm_head_dim if cfg.ssm_state else 0

    n_attn = 0
    if cfg.family in ("dense", "audio", "moe"):
        n_attn = cfg.n_layers
    elif cfg.family == "vlm":
        n_attn = cfg.n_layers  # self + cross both attend
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.shared_attn_every
    n_mamba = 0
    if cfg.family == "ssm":
        n_mamba = cfg.n_layers
    elif cfg.family == "hybrid":
        n_mamba = cfg.n_layers

    attn_dim = cfg.n_heads * cfg.hd if cfg.n_heads else 0

    if shape.kind == "decode":
        tokens = B
        f = 2.0 * n_active * tokens
        f += 4.0 * n_attn * B * S * attn_dim  # score+mix against the cache
        f += 5.0 * n_mamba * B * n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
        return f

    tokens = B * S
    mult = 6.0 if shape.kind == "train" else 2.0
    f = mult * n_active * tokens
    # causal attention useful flops: 2*B*S^2*attn_dim fwd per layer (half of
    # the full S^2 score/mix matmuls), x3 for train
    f += (mult / 2.0) * 2.0 * n_attn * B * S * S * attn_dim
    # SSD: chunked matmuls ~ 2*B*S*(Q + 2N)*d_inner fwd per layer
    q = cfg.ssd_chunk
    f += (mult / 2.0) * 2.0 * n_mamba * B * S * (q + 2 * cfg.ssm_state) * d_inner
    return f


# --------------------------------------------------------------------------
# cell runner
# --------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, mesh_kind: str, overrides: dict,
             out_dir: str | None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        print(f"SKIP {arch} x {shape_name}: full-attention arch at 500k "
              "(DESIGN.md §7)")
        return dict(arch=arch, shape=shape_name, mesh=mesh_kind,
                    skipped="full-attention long-context")
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    lm, lowered, extra = build_lowered(cfg, shape, mesh, overrides)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # loop-aware accounting (XLA's cost_analysis counts while bodies once —
    # with scan-over-layers that undercounts by ~n_layers; see hlo_cost.py)
    la = hlo_analyze(hlo)

    flops_dev = float(la["flops"])
    bytes_dev = float(la["hbm_bytes"])
    coll_dev = float(la["collective_bytes"])

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    coll_s = coll_dev / LINK_BW
    terms = dict(compute_s=compute_s, memory_s=memory_s, collective_s=coll_s)
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape, lm)
    mf_dev = mf / n_chips
    useful = mf_dev / flops_dev if flops_dev else 0.0

    mem = dict(
        argument_bytes=int(ma.argument_size_in_bytes),
        output_bytes=int(ma.output_size_in_bytes),
        temp_bytes=int(ma.temp_size_in_bytes),
        alias_bytes=int(ma.alias_size_in_bytes),
        peak_estimate_gib=round(
            (ma.argument_size_in_bytes + ma.output_size_in_bytes
             + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3
        ),
    )

    rec = dict(
        arch=arch, shape=shape_name, mesh=mesh_kind, chips=n_chips,
        params=lm.param_count(), active_params=lm.active_param_count(),
        per_device=dict(flops=flops_dev, hbm_bytes=bytes_dev,
                        collective_bytes=coll_dev),
        collectives=la["collectives"],
        xla_cost_analysis=dict(
            flops=float(ca.get("flops", 0.0)),
            bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        ),
        top_dots=[[round(f / 1e9, 2), m] for f, m in la["top_dots"][:8]],
        top_collectives=[
            [round(b / 1e9, 3), m] for b, m in la["top_collectives"][:8]
        ],
        terms_s=terms, dominant=dominant,
        model_flops_global=mf, useful_flops_ratio=round(useful, 4),
        roofline_bound_s=max(terms.values()),
        memory=mem,
        lower_s=round(t1 - t0, 1), compile_s=round(t2 - t1, 1),
        **extra, **{f"override_{k}": v for k, v in overrides.items()},
    )
    print(json.dumps(rec, indent=2))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = overrides.get("tag", "base")
        path = os.path.join(
            out_dir, f"{arch}__{shape_name}__{mesh_kind}__{tag}.json"
        )
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
    return rec


def run_all(mesh_kinds: list[str], out_dir: str, timeout: int):
    """Drive every cell in an isolated subprocess (compile-cache hygiene +
    a hung compile cannot take down the sweep)."""
    failures = []
    for arch in ARCH_IDS:
        for shape_name in SHAPES:
            for mk in mesh_kinds:
                cfg = get_config(arch)
                if not shape_applicable(cfg, SHAPES[shape_name]):
                    continue
                tag = "base"
                path = os.path.join(
                    out_dir, f"{arch}__{shape_name}__{mk}__{tag}.json"
                )
                if os.path.exists(path):
                    print(f"cached: {path}")
                    continue
                cmd = [
                    sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", arch, "--shape", shape_name, "--mesh", mk,
                    "--out-dir", out_dir,
                ]
                print("RUN", " ".join(cmd), flush=True)
                try:
                    r = subprocess.run(cmd, timeout=timeout)
                    if r.returncode != 0:
                        failures.append((arch, shape_name, mk, r.returncode))
                except subprocess.TimeoutExpired:
                    failures.append((arch, shape_name, mk, "timeout"))
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("all cells OK")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--timeout", type=int, default=1800)
    # perf-iteration overrides
    ap.add_argument("--microbatches", type=int)
    ap.add_argument("--skip-masked", action="store_true")
    ap.add_argument("--moment-dtype")
    ap.add_argument("--master-dtype")
    ap.add_argument("--accum-dtype")
    ap.add_argument("--cache-shard", choices=["auto", "heads", "hd", "seq"])
    ap.add_argument("--tag", default="base")
    args = ap.parse_args()

    overrides = {}
    for k in ("microbatches", "moment_dtype", "master_dtype", "accum_dtype",
              "cache_shard"):
        v = getattr(args, k)
        if v is not None:
            overrides[k] = v
    if args.skip_masked:
        overrides["skip_masked"] = True
    if args.tag != "base":
        overrides["tag"] = args.tag

    kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        run_all(kinds, args.out_dir, args.timeout)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mk in kinds:
            run_cell(args.arch, args.shape, mk, overrides, args.out_dir)


if __name__ == "__main__":
    main()
