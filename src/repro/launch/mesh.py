"""Production mesh construction.

Mesh shapes (TPU v5e):
  single-pod: (16, 16)      axes ("data", "model")   = 256 chips
  multi-pod:  (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run pins the host-device count before first jax use).
"""
from __future__ import annotations

import numpy as np

import jax


def make_mesh(shape, axes, devices=None):
    """``jax.make_mesh`` across the AxisType API drift: newer JAX wants
    explicit ``axis_types``; 0.4.x has neither ``jax.sharding.AxisType`` nor
    the kwarg. All mesh construction in this repo goes through here."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    kwargs = {} if devices is None else dict(devices=devices)
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axes)
    try:
        return jax.make_mesh(shape, axes, **kwargs)
    except TypeError:  # older make_mesh without devices kwarg
        from jax.sharding import Mesh

        devs = devices if devices is not None else jax.devices()
        need = int(np.prod(shape))
        return Mesh(np.asarray(devs[:need]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this automatically)"
        )
    return make_mesh(shape, axes, devices=devices[:need])


def data_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a production mesh (pod extends DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
