"""Loop-aware cost model over post-optimization HLO text.

XLA's HloCostAnalysis counts a ``while`` body ONCE regardless of trip count
— with scan-over-layers models that undercounts FLOPs/bytes/collectives by
~n_layers (verified empirically; EXPERIMENTS.md §Dry-run calibration). This
module parses the compiled module into its computation call graph and rolls
costs up with multipliers:

  while ops      x trip count (parsed from the condition computation:
                 max integer constant, +1 when the compare is LE)
  fusion/call    x 1 per call site (fusions are opaque for BYTE accounting —
                 operands+result of the fusion op model post-fusion HBM
                 traffic — but transparent for DOT flops and collectives)
  conditional    x max over branches

Per-module outputs (per-device, since SPMD executables are per-partition):
  flops            2 * numel(result) * prod(contracted dims) per dot
  hbm_bytes        sum over non-free ops of operand+result bytes
  collective_bytes operand bytes of all-gather / all-reduce / reduce-scatter
                   / all-to-all / collective-permute (+ ...-start forms)
  breakdown        per-opcode flops and per-collective bytes

This is also the §Perf profiling tool: ``dot_sites()`` lists the heaviest
dots with their source metadata so a hillclimb iteration can see WHERE the
flops moved.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _type_bytes(t: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(t):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(t: str) -> Optional[list[int]]:
    m = _SHAPE_RE.search(t)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    attrs: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    params: dict  # name -> type string
    ops: list[Op]


_COMP_HEADER = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{\s*$"
)
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^()]*\)|[\w\[\],\{\}\d]+)\s+"
    r"([\w\-]+)\((.*)$"
)

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "reshape", "after-all", "add-dependency", "iota",
    "partition-id", "replica-id", "rng-get-and-update-state",
    "get-dimension-size",
}
_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}


def parse_module(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m:
                name, params_str, _ret = m.groups()
                params = {}
                for p in re.findall(r"%?([\w.\-]+):\s*([^,()]+(?:\([^)]*\))?)",
                                    params_str):
                    params[p[0]] = p[1]
                cur = Computation(name, params, [])
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if m:
            name, type_str, opcode, rest = m.groups()
            depth, end = 1, len(rest)
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operand_str = rest[:end]
            attrs = rest[end + 1:]
            opnames = re.findall(r"%([\w.\-]+)", operand_str)
            if not opnames:
                # operands referenced without % (older dialect)
                opnames = [
                    t for t in re.findall(r"([\w.\-]+)", operand_str)
                    if not re.fullmatch(r"[\d.]+", t)
                ]
            cur.ops.append(Op(name, type_str, opcode, opnames, attrs, line))
    return comps


def _trip_count(cond: Computation, comps: dict[str, Computation]) -> int:
    consts = []
    le = False
    stack = [cond]
    seen = set()
    while stack:
        c = stack.pop()
        if c.name in seen:
            continue
        seen.add(c.name)
        for op in c.ops:
            if op.opcode == "constant":
                m = re.search(r"constant\((-?\d+)\)", op.line)
                if m:
                    consts.append(int(m.group(1)))
            if "direction=LE" in op.attrs or "direction=LE" in op.line:
                le = True
            for target in re.findall(r"(?:calls|to_apply)=%?([\w.\-]+)",
                                     op.attrs):
                if target in comps:
                    stack.append(comps[target])
    trip = max([c for c in consts if c >= 0], default=1)
    return trip + 1 if le else max(trip, 1)


def _dot_flops(op: Op, shape_of) -> int:
    res_dims = _first_shape_dims(op.type_str) or []
    numel = 1
    for d in res_dims:
        numel *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    k = 1
    if m and op.operands:
        lhs_t = shape_of(op.operands[0])
        lhs_dims = _first_shape_dims(lhs_t) if lhs_t else None
        if lhs_dims is not None and m.group(1):
            for ci in m.group(1).split(","):
                ci = int(ci)
                if ci < len(lhs_dims):
                    k *= lhs_dims[ci]
    return 2 * numel * k


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    coll_breakdown: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    dot_sites: list = dataclasses.field(default_factory=list)
    coll_sites: list = dataclasses.field(default_factory=list)

    def add(self, other: "Cost", mult: float) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for kk, vv in other.coll_breakdown.items():
            self.coll_breakdown[kk] += vv * mult
        for (f, meta) in other.dot_sites:
            self.dot_sites.append((f * mult, meta))
        for (b, meta) in other.coll_sites:
            self.coll_sites.append((b * mult, meta))


class ModuleCost:
    def _fusion_read_bytes(self, op: Op, called: list, shape_of) -> float:
        """Model HBM reads of a fusion: a parameter consumed ONLY through
        slicing ops inside the fusion contributes slice-result bytes, not the
        whole (possibly loop-carried) buffer."""
        total = 0.0
        sliced_params: dict[int, float] = {}
        for target in called:
            comp = self.comps.get(target)
            if comp is None:
                continue
            # param order == operand order
            pnames = list(comp.params)
            consumers: dict[str, list[Op]] = defaultdict(list)
            for iop in comp.ops:
                for o in iop.operands:
                    consumers[o].append(iop)
            for i, pn in enumerate(pnames):
                cons = consumers.get(pn, [])
                if cons and all(
                    c.opcode in ("dynamic-slice", "slice", "gather")
                    for c in cons
                ):
                    sliced_params[i] = sum(
                        _type_bytes(c.type_str) for c in cons
                    )
        for i, o in enumerate(op.operands):
            if i in sliced_params:
                total += sliced_params[i]
            else:
                total += _type_bytes(shape_of(o) or "")
        return total

    def __init__(self, hlo: str):
        self.comps = parse_module(hlo)
        self.entry = None
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
        if m:
            self.entry = m.group(1)
        else:  # fall back: computation named main-ish
            for name in self.comps:
                if "main" in name:
                    self.entry = name
                    break
        self._memo: dict[str, Cost] = {}

    def _comp_cost(self, cname: str) -> Cost:
        if cname in self._memo:
            return self._memo[cname]
        comp = self.comps[cname]
        shapes = dict(comp.params)
        for op in comp.ops:
            shapes[op.name] = op.type_str

        def shape_of(name: str) -> Optional[str]:
            return shapes.get(name)

        cost = Cost()
        self._memo[cname] = cost  # cycles guard

        # consumer map for the reduce-scatter-equivalence correction:
        # XLA:CPU lacks ReduceScatterCreator, so a sharded partial-sum lowers
        # to all-reduce + partition-id-keyed dynamic-slice. On TPU that same
        # program is a reduce-scatter moving 1/G of the bytes. Detect the
        # pattern and count TPU-equivalent wire bytes (raw kind kept in the
        # 'all-reduce(cpu)' breakdown entry for transparency).
        consumers: dict[str, list[Op]] = defaultdict(list)
        for iop in comp.ops:
            for o in iop.operands:
                consumers[o].append(iop)

        def _is_slice_fusion(c: Op) -> bool:
            if "partition-id" not in c.line and not any(
                "partition-id" in x for x in c.operands
            ):
                # fusion operand may be a partition-id op by name
                ops_here = {o for o in c.operands}
                if not any("partition-id" in o for o in ops_here):
                    pass
            for target in re.findall(r"calls=%?([\w.\-]+)", c.attrs):
                tc = self.comps.get(target)
                if tc and any(
                    o.opcode in ("dynamic-slice",) for o in tc.ops
                ):
                    return True
            return c.opcode == "dynamic-slice"

        def _group_size(op: Op) -> int:
            m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.attrs)
            if m:
                return max(int(m.group(2)), 1)
            m = re.search(r"replica_groups=\{\{([\d,]+)\}", op.attrs)
            if m:
                return max(len(m.group(1).split(",")), 1)
            return 1

        for op in comp.ops:
            oc = op.opcode
            base = oc[:-6] if oc.endswith("-start") else oc
            if base in _COLLECTIVES:
                b = sum(
                    _type_bytes(shape_of(o) or "") for o in op.operands
                ) or _type_bytes(op.type_str)
                kind = base
                if base == "all-reduce":
                    # BFS through transitive consumers (converts/adds/-done)
                    # looking for the partition-keyed slice that proves the
                    # value is only ever used sharded
                    frontier = [op.name]
                    found = False
                    for _ in range(4):
                        nxt = []
                        for nm in frontier:
                            for c in consumers.get(nm, []):
                                if _is_slice_fusion(c):
                                    found = True
                                elif c.opcode in (
                                    "convert", "add", "multiply", "fusion",
                                    "copy", "tuple", "get-tuple-element",
                                ) or c.opcode.endswith("-done"):
                                    if c.opcode == "fusion" and _is_slice_fusion(c):
                                        found = True
                                    nxt.append(c.name)
                        frontier = nxt
                        if found or not frontier:
                            break
                    if found:
                        g = _group_size(op)
                        if g > 1:
                            cost.coll_breakdown["all-reduce(cpu-raw)"] += b
                            b = b / g
                            kind = "reduce-scatter"
                cost.collective_bytes += b
                cost.coll_breakdown[kind] += b
                cost.hbm_bytes += b
                meta = re.search(r'op_name="([^"]*)"', op.attrs)
                cost.coll_sites.append(
                    (b, kind + " " + (meta.group(1) if meta else op.name))
                )
                continue
            if oc == "while":
                body = re.search(r"body=%?([\w.\-]+)", op.attrs)
                cond = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                if body and body.group(1) in self.comps:
                    trip = (
                        _trip_count(self.comps[cond.group(1)], self.comps)
                        if cond and cond.group(1) in self.comps
                        else 1
                    )
                    cost.add(self._comp_cost(body.group(1)), trip)
                continue
            if oc == "conditional":
                branches = re.findall(
                    r"(?:branch_computations=\{([^}]*)\}|"
                    r"(?:true|false)_computation=%?([\w.\-]+))", op.attrs
                )
                names = []
                for grp in branches:
                    for g in grp:
                        if g:
                            names.extend(
                                re.findall(r"%?([\w.\-]+)", g)
                            )
                sub = [
                    self._comp_cost(n) for n in names if n in self.comps
                ]
                if sub:
                    best = max(sub, key=lambda c: c.flops + c.hbm_bytes)
                    cost.add(best, 1.0)
                continue
            if oc == "scatter":
                # in-place: traffic ~ indices + 2x updates (read-mod-write),
                # not the whole target buffer
                upd = (
                    sum(_type_bytes(shape_of(o) or "") for o in op.operands[1:])
                    if len(op.operands) > 2 else _type_bytes(op.type_str)
                )
                cost.hbm_bytes += 2 * upd
                continue
            if oc in ("fusion", "call", "custom-call", "map", "reduce",
                      "reduce-window", "sort", "select-and-scatter"):
                # dots/collectives inside called computations still count
                called = re.findall(
                    r"(?:calls|to_apply)=%?([\w.\-]+)", op.attrs
                )
                for target in called:
                    if target in self.comps:
                        sub = self._comp_cost(target)
                        inner = Cost()
                        inner.flops = sub.flops
                        inner.collective_bytes = sub.collective_bytes
                        inner.coll_breakdown = sub.coll_breakdown
                        inner.dot_sites = sub.dot_sites
                        # bytes stay at the call-site level (post-fusion)
                        cost.add(inner, 1.0)
                if oc != "call":
                    cost.hbm_bytes += _type_bytes(op.type_str)
                    cost.hbm_bytes += self._fusion_read_bytes(
                        op, called, shape_of
                    )
                continue
            if oc in ("dynamic-slice", "slice", "gather"):
                # reads only the touched slice; result-sized traffic x2
                cost.hbm_bytes += 2 * _type_bytes(op.type_str)
                continue
            if oc == "dynamic-update-slice":
                # in-place: writes the update region only
                upd = (
                    _type_bytes(shape_of(op.operands[1]) or "")
                    if len(op.operands) > 1 else _type_bytes(op.type_str)
                )
                cost.hbm_bytes += 2 * upd
                continue
            if oc in ("broadcast", "iota", "copy-start", "copy-done"):
                cost.hbm_bytes += _type_bytes(op.type_str)
                continue
            if oc in ("dot", "convolution"):
                f = _dot_flops(op, shape_of)
                cost.flops += f
                meta = re.search(r'op_name="([^"]*)"', op.attrs)
                cost.dot_sites.append((f, meta.group(1) if meta else op.name))
                cost.hbm_bytes += _type_bytes(op.type_str) + sum(
                    _type_bytes(shape_of(o) or "") for o in op.operands
                )
                continue
            if oc in _FREE_OPS:
                continue
            # default: elementwise-ish op — count operand+result traffic
            cost.hbm_bytes += _type_bytes(op.type_str) + sum(
                _type_bytes(shape_of(o) or "") for o in op.operands
            )
        self._memo[cname] = cost
        return cost

    def total(self) -> Cost:
        if self.entry is None:
            raise ValueError("no ENTRY computation found")
        return self._comp_cost(self.entry)

    def top_dots(self, n: int = 12) -> list[tuple[float, str]]:
        agg: dict[str, float] = defaultdict(float)
        for f, meta in self.total().dot_sites:
            agg[meta] += f
        return sorted(((v, k) for k, v in agg.items()), reverse=True)[:n]

    def top_collectives(self, n: int = 12) -> list[tuple[float, str]]:
        agg: dict[str, float] = defaultdict(float)
        for b, meta in self.total().coll_sites:
            agg[meta] += b
        return sorted(((v, k) for k, v in agg.items()), reverse=True)[:n]


def analyze(hlo: str) -> dict:
    mc = ModuleCost(hlo)
    c = mc.total()
    return dict(
        flops=c.flops,
        hbm_bytes=c.hbm_bytes,
        collective_bytes=c.collective_bytes,
        collectives={k: v for k, v in sorted(c.coll_breakdown.items())},
        top_dots=[(f, m) for f, m in mc.top_dots()],
        top_collectives=[(b, m) for b, m in mc.top_collectives()],
    )
