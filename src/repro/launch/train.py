"""Production training driver: fault-tolerant, elastic, resumable.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ckpt

Fault-tolerance contract:
  * SIGTERM/SIGINT -> finish the in-flight step, checkpoint, exit 0
    (preemption-safe);
  * restart with the same --ckpt-dir resumes from the latest step —
    bit-exact, because the data pipeline is seekable by step;
  * ELASTIC: the restart may use a different device count / mesh shape —
    checkpoints are stored unsharded and are device_put into the new mesh's
    shardings (train/checkpoint.py).

Diversity-maximized data selection (the paper's technique) is ON by default
(--no-diverse-data to ablate): every batch is picked from an over-decomposed
candidate pool by the jit'd coreset selector (data/pipeline.py).
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, get_config
from ..data.pipeline import DataConfig, Pipeline
from .mesh import make_mesh
from ..models.model import LM
from ..models.sharding import param_specs, set_activation_mesh
from ..train.checkpoint import CheckpointManager
from ..train.optimizer import AdamWConfig
from ..train.train_state import (
    StepConfig,
    abstract_train_state,
    init_train_state,
    make_train_step,
)

_STOP = {"flag": False}


def _handle_sig(signum, frame):
    print(f"[train] signal {signum}: will checkpoint and exit after this step")
    _STOP["flag"] = True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-diverse-data", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-axis-size", type=int, default=0,
                    help="0 = all local devices on one data axis")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    lm = LM(cfg)
    print(f"[train] {cfg.name}: {lm.param_count():,} params "
          f"({'reduced' if args.reduced else 'full'})")

    n_dev = args.data_axis_size or len(jax.devices())
    mesh = make_mesh((n_dev,), ("data",))
    set_activation_mesh(("data",) if args.batch % n_dev == 0 else None, None)

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=min(100, args.steps // 10 + 1))
    step_cfg = StepConfig(microbatches=args.microbatches)
    pspecs = param_specs(lm.abstract_params(), ("data",), tp=None)
    train_step = make_train_step(lm, opt_cfg, step_cfg, grad_specs=pspecs)
    opt_specs = {"m": pspecs, "v": pspecs, "step": P()}
    state_abs = abstract_train_state(lm, opt_cfg)
    if "master" in state_abs["opt"]:
        opt_specs["master"] = pspecs
    sspecs = {"params": pspecs, "opt": opt_specs, "step": P()}

    def ns(tree):
        return jax.tree.map(
            lambda sp: NamedSharding(mesh, sp), tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    batch_sharding = NamedSharding(
        mesh, P("data" if args.batch % n_dev == 0 else None)
    )
    jstep = jax.jit(
        train_step,
        in_shardings=(ns(sspecs), {"tokens": batch_sharding}),
        out_shardings=(ns(sspecs), None),
        donate_argnums=(0,),
    )

    data_cfg = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        diverse_selection=not args.no_diverse_data, seed=args.seed,
    )
    pipe = Pipeline(data_cfg)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    with mesh:
        if mgr and mgr.latest_step() is not None:
            start = mgr.latest_step()
            print(f"[train] resuming from step {start} "
                  f"(elastic restore onto {n_dev} devices)")
            state = mgr.restore(start, state_abs, ns(sspecs))
        else:
            state = jax.jit(
                lambda: init_train_state(lm, jax.random.PRNGKey(args.seed),
                                         opt_cfg),
                out_shardings=ns(sspecs),
            )()

        signal.signal(signal.SIGTERM, _handle_sig)
        signal.signal(signal.SIGINT, _handle_sig)

        t0 = time.perf_counter()
        tokens_done = 0
        for step in range(start, args.steps):
            batch = pipe.batch_at(step)
            state, metrics = jstep(state, {"tokens": batch["tokens"]})
            tokens_done += args.batch * args.seq
            if (step + 1) % args.log_every == 0 or step == start:
                loss = float(metrics["loss"])
                gn = float(metrics["grad_norm"])
                dt = time.perf_counter() - t0
                print(f"[train] step {step+1:5d} loss {loss:.4f} "
                      f"gnorm {gn:.3f} tok/s {tokens_done/dt:,.0f}")
                if not np.isfinite(loss):
                    raise RuntimeError("NaN/Inf loss — aborting")
            if mgr and ((step + 1) % args.ckpt_every == 0 or _STOP["flag"]):
                mgr.save(step + 1, state)
            if _STOP["flag"]:
                if mgr:
                    mgr.wait()
                print(f"[train] clean preemption exit at step {step+1}")
                return
        if mgr:
            mgr.save(args.steps, state)
            mgr.wait()
        print(f"[train] done: {args.steps} steps, "
              f"final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
